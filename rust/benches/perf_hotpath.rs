//! Bench: the performance-critical paths across all three layers, tracked
//! by EXPERIMENTS.md §Perf, `BENCH_exec.json` and `BENCH_gemm.json`.
//!
//! * Exec engine: compiled chip-plan executor vs the naive PE-chain
//!   simulator on the paper's 256×256 array, across a fault-rate sweep,
//!   single-threaded and pooled (MAC/s + speedup, emitted as
//!   `BENCH_exec.json` so the perf trajectory is tracked PR over PR).
//! * GEMM kernel: the dispatched packed-panel microkernel (AVX2/NEON/
//!   scalar, i8 panels) vs the column-at-a-time `dot_wrapping` baseline
//!   at the fig2a mnist MLP shapes, `simd_vs_scalar` rows against the
//!   PR-4 scalar 4×4 microkernel, `i8_vs_i32_panel` rows isolating the
//!   narrow-panel win, plus pool-vs-scope dispatch rows at serving batch
//!   sizes (`BENCH_gemm.json`; meta records the dispatched ISA).
//!   **Parity-gated**: every timed variant's output is compared
//!   bit-for-bit and a mismatch exits nonzero — the CI quick-bench smoke
//!   fails on parity, never on timing.
//! * Train engine: the packed-panel f32 SIMD trainer vs the naive seed
//!   scalar step, single-thread and pooled, plus measured FAP+T retrain
//!   wall minutes at the Fig 5 campaign shape vs the paper's 12-minute
//!   budget (`BENCH_train.json`). **Parity-gated** bit-for-bit across
//!   ISAs, panel widths and pool lane counts.
//! * L3 sim: functional systolic matmul (MAC/s) — target ≥100M MAC/s/core.
//! * L3 masks: LayerMasks synthesis for the TIMIT model on a 256 grid.
//! * RT (needs `artifacts/`): PJRT fwd latency/throughput (mnist + timit),
//!   train-step latency, and the scan-fused multi-step training artifact
//!   vs N single steps. Skipped with a notice when artifacts are absent.
//!
//! `REPRO_BENCH_QUICK=1` shrinks every section to CI-smoke size (seconds,
//! not minutes) while keeping all parity gates live.

use repro::chip::{Backend, Chip, Engine};
use repro::coordinator::trainer::{
    he_init, native_train_step, native_train_step_fast, ones_masks, run_steps_native_pooled,
    train_step, NativeTrainState, TrainConfig, TrainScratch, TrainState,
};
use repro::coordinator::{fapt_retrain_native_pooled, FaptConfig};
use repro::data::{self, Dataset};
use repro::exec::{
    default_threads, dot_wrapping, kernel, Isa, Kernel, MatmulPlan, PanelOptions, WorkerPool,
};
use repro::faults::{inject_uniform, FaultMap, FaultSpec};
use repro::fleet::{
    percentile, serve, serve_open, ArrivalProcess, BatcherConfig, ChipUnit, OpenLoopStats,
    OpenWorkloadConfig, RoutingPolicy, WorkloadConfig,
};
use repro::mapping::{LayerMasks, MaskKind};
use repro::model::arch;
use repro::model::quant::calibrate_mlp;
use repro::model::{Arch, Layer, Params};
use repro::runtime::{lit_f32, lit_i32, scalar_f32, Runtime};
use repro::systolic::{timing, TiledMatmul};
use repro::util::bench;
use repro::util::json::Json;
use repro::util::Rng;

/// Naive-vs-plan sweep on the paper's 256×256 array; records MAC/s and
/// speedups (single-thread and pooled) as `BENCH_exec.json` rows.
/// Returns `(meta, rows)` so the file meta always matches the sweep
/// geometry actually run.
fn bench_exec_engine(rng: &mut Rng, quick: bool) -> anyhow::Result<(Json, Vec<Json>)> {
    let (n, b, k, m) = if quick { (32, 16usize, 96usize, 96usize) } else { (256, 64, 512, 512) };
    // fault counts over the grid: 0%, ~0.4%, 6.25%, 25% (quick: 0%, 6.25%)
    let fault_counts: Vec<usize> =
        if quick { vec![0, n * n / 16] } else { vec![0, 256, 4096, 16384] };
    let (naive_wu, naive_it, plan_wu, plan_it) = if quick { (0, 1, 1, 3) } else { (1, 3, 2, 10) };
    println!("# exec engine: compiled plan vs naive PE-chain (n={n})");
    let macs = timing::mac_ops(b, k, m);
    let threads = default_threads().max(4);
    let pool = WorkerPool::new(threads);
    let a: Vec<i32> = (0..b * k).map(|_| rng.below(255) as i32 - 127).collect();
    let w: Vec<i32> = (0..k * m).map(|_| rng.below(255) as i32 - 127).collect();

    let mut results = Vec::new();
    for &faults in &fault_counts {
        let fm = inject_uniform(FaultSpec::new(n), faults, &mut Rng::new(97 ^ faults as u64));
        for (kind, label) in [
            (MaskKind::Unmitigated, "unmitigated"),
            (MaskKind::FapBypass, "fap-bypass"),
        ] {
            // the bypass scenario only differs once there are faults
            if faults == 0 && kind == MaskKind::FapBypass {
                continue;
            }
            let byp = kind == MaskKind::FapBypass;
            let mut tm = TiledMatmul::new(&fm, byp);
            let mut out = vec![0i32; b * m];
            let naive = bench::bench(
                &format!("naive chain ({faults} faults, {label})"),
                naive_wu,
                naive_it,
                || {
                    tm.matmul_into(&a, &w, b, k, m, &mut out);
                    bench::black_box(&mut out);
                },
            );
            naive.report_throughput(macs, "MAC");
            let want = out.clone(); // oracle output for the parity gates

            let plan = MatmulPlan::compile(&fm, kind, &w, k, m);
            let single = bench::bench(
                &format!("plan x1 thread ({faults} faults, {label})"),
                plan_wu,
                plan_it,
                || {
                    plan.execute_into(&a, b, &mut out);
                    bench::black_box(&mut out);
                },
            );
            single.report_throughput(macs, "MAC");
            anyhow::ensure!(out == want, "parity: plan x1 != naive ({faults} faults, {label})");
            let multi = bench::bench(
                &format!("plan x{threads} pooled ({faults} faults, {label})"),
                plan_wu,
                plan_it,
                || {
                    plan.execute_pooled_into(&a, b, &pool, &mut out);
                    bench::black_box(&mut out);
                },
            );
            multi.report_throughput(macs, "MAC");
            anyhow::ensure!(out == want, "parity: pooled != naive ({faults} faults, {label})");

            let speedup_single =
                naive.median.as_secs_f64() / single.median.as_secs_f64().max(1e-12);
            let speedup_multi = naive.median.as_secs_f64() / multi.median.as_secs_f64().max(1e-12);
            let stats = plan.stats();
            println!(
                "  -> speedup x1={speedup_single:.1} x{threads}={speedup_multi:.1} \
                 (dense {} / folded {} / chain {} cols)",
                stats.dense_cols, stats.folded_cols, stats.chain_cols
            );
            results.push(
                Json::obj()
                    .field("faulty_macs", Json::num(faults as f64))
                    .field("mitigation", Json::str(label))
                    .field("threads", Json::num(threads as f64))
                    .field("macs", Json::num(macs as f64))
                    .field("naive", naive.to_json())
                    .field("plan_single", single.to_json())
                    .field("plan_threaded", multi.to_json())
                    .field("naive_macs_per_s", Json::num(naive.throughput(macs)))
                    .field("plan_single_macs_per_s", Json::num(single.throughput(macs)))
                    .field("plan_threaded_macs_per_s", Json::num(multi.throughput(macs)))
                    .field("speedup_single", Json::num(speedup_single))
                    .field("speedup_threaded", Json::num(speedup_multi)),
            );
        }
    }
    let meta = Json::obj()
        .field("array_n", Json::num(n as f64))
        .field("batch", Json::num(b as f64))
        .field("k", Json::num(k as f64))
        .field("m", Json::num(m as f64))
        .field("threads", Json::num(threads as f64));
    Ok((meta, results))
}

/// The column-at-a-time dot-product GEMM this PR replaced — kept inline
/// as the `BENCH_gemm.json` baseline. `wcols` is column-major `[m][k]`
/// with fault folding already applied (the pre-packing compile layout).
fn dot_gemm_into(a: &[i32], wcols: &[i32], b: usize, k: usize, m: usize, out: &mut [i32]) {
    for j in 0..m {
        let col = &wcols[j * k..(j + 1) * k];
        for bi in 0..b {
            out[bi * m + j] = dot_wrapping(&a[bi * k..(bi + 1) * k], col);
        }
    }
}

/// Microkernel-vs-dot, SIMD-vs-scalar and i8-vs-i32-panel rows at the
/// fig2a mnist MLP shapes, plus pool-vs-scope dispatch rows at serving
/// batch sizes — `BENCH_gemm.json` (meta records the dispatched ISA and
/// panel width). Every variant is parity-gated bit-for-bit (in quick
/// mode additionally against the cycle-level oracle); a mismatch aborts
/// the bench with a nonzero exit, which is what the CI smoke asserts.
fn bench_gemm_micro(rng: &mut Rng, quick: bool) -> anyhow::Result<(Json, Vec<Json>)> {
    let n = if quick { 32 } else { 256 };
    let batch = if quick { 16usize } else { 64 };
    // fig2a mnist MLP layer shapes (din x dout), shrunk under quick
    let shapes: &[(usize, usize)] =
        if quick { &[(96, 64), (64, 10)] } else { &[(784, 256), (256, 256), (256, 10)] };
    let (wu, it) = if quick { (1, 3) } else { (2, 10) };
    let kr = kernel();
    let scalar_kr = Kernel::scalar_fallback();
    println!(
        "\n# gemm: dispatched microkernel ({} x{}) vs column-dot baseline (n={n}, batch {batch})",
        kr.isa().name(),
        kr.nr()
    );

    let mut rows = Vec::new();
    for &(k, m) in shapes {
        let a: Vec<i32> = (0..batch * k).map(|_| rng.below(255) as i32 - 127).collect();
        let w: Vec<i32> = (0..k * m).map(|_| rng.below(255) as i32 - 127).collect();
        for (faults, kind, label) in [
            (0usize, MaskKind::Unmitigated, "healthy"),
            (n * n / 16, MaskKind::FapBypass, "6.25% fap-bypass"),
        ] {
            let fm = inject_uniform(
                FaultSpec::new(n),
                faults,
                &mut Rng::new(33 ^ (k * 31 + m + faults) as u64),
            );
            // fold bypassed MACs to zero columns, column-major — exactly
            // what the old compile produced for the dot walk
            let mut wcols = vec![0i32; k * m];
            for j in 0..m {
                for kk in 0..k {
                    let byp = kind == MaskKind::FapBypass && fm.is_faulty(kk % n, j % n);
                    wcols[j * k + kk] = if byp { 0 } else { w[kk * m + j] };
                }
            }
            let macs = timing::mac_ops(batch, k, m);
            let mut out_dot = vec![0i32; batch * m];
            let dot = bench::bench(&format!("col-dot {k}x{m} ({label})"), wu, it, || {
                dot_gemm_into(&a, &wcols, batch, k, m, &mut out_dot);
                bench::black_box(&mut out_dot);
            });
            dot.report_throughput(macs, "MAC");

            // the default compile: dispatched panel width, i8 panels (the
            // quantized-range weights above always qualify)
            let plan = MatmulPlan::compile(&fm, kind, &w, k, m);
            anyhow::ensure!(
                plan.stats().i8_tiles == plan.stats().tiles,
                "quantized-range weights must pack i8 panels"
            );
            let mut out_packed = vec![0i32; batch * m];
            let packed = bench::bench(&format!("packed simd {k}x{m} ({label})"), wu, it, || {
                plan.execute_into(&a, batch, &mut out_packed);
                bench::black_box(&mut out_packed);
            });
            packed.report_throughput(macs, "MAC");

            // parity gate: packed microkernel == dot baseline, bit-for-bit
            anyhow::ensure!(
                out_packed == out_dot,
                "parity: packed != col-dot at {k}x{m} ({label})"
            );
            if quick {
                // CI smoke: cross-check the cycle-level oracle too
                let want = TiledMatmul::new(&fm, kind == MaskKind::FapBypass)
                    .matmul(&a, &w, batch, k, m);
                anyhow::ensure!(
                    out_packed == want,
                    "parity: packed != cycle oracle at {k}x{m} ({label})"
                );
            }
            let speedup = dot.median.as_secs_f64() / packed.median.as_secs_f64().max(1e-12);
            println!("  -> packed speedup x1 = {speedup:.2}");
            rows.push(
                Json::obj()
                    .field("row", Json::str("micro_vs_dot"))
                    .field("isa", Json::str(kr.isa().name()))
                    .field("k", Json::num(k as f64))
                    .field("m", Json::num(m as f64))
                    .field("batch", Json::num(batch as f64))
                    .field("faulty_macs", Json::num(faults as f64))
                    .field("mitigation", Json::str(label))
                    .field("macs", Json::num(macs as f64))
                    .field("dot", dot.to_json())
                    .field("packed", packed.to_json())
                    .field("dot_macs_per_s", Json::num(dot.throughput(macs)))
                    .field("packed_macs_per_s", Json::num(packed.throughput(macs)))
                    .field("speedup_packed", Json::num(speedup)),
            );

            // SIMD vs the PR-4 scalar 4x4 microkernel: same fault folding,
            // panels re-packed at the scalar width — exactly what every
            // build before runtime dispatch executed
            let plan_pr4 = MatmulPlan::compile_opts(
                &fm,
                kind,
                &w,
                k,
                m,
                PanelOptions { nr: scalar_kr.nr(), allow_i8: false },
            );
            let mut out_scalar = vec![0i32; batch * m];
            let scalar = bench::bench(&format!("scalar 4x4 {k}x{m} ({label})"), wu, it, || {
                plan_pr4.execute_with_kernel_into(&scalar_kr, &a, batch, &mut out_scalar);
                bench::black_box(&mut out_scalar);
            });
            scalar.report_throughput(macs, "MAC");
            anyhow::ensure!(
                out_scalar == out_packed,
                "parity: scalar 4x4 != dispatched at {k}x{m} ({label})"
            );
            let speedup_simd = scalar.median.as_secs_f64() / packed.median.as_secs_f64().max(1e-12);
            println!("  -> {} speedup over scalar 4x4 = {speedup_simd:.2}", kr.isa().name());
            rows.push(
                Json::obj()
                    .field("row", Json::str("simd_vs_scalar"))
                    .field("isa", Json::str(kr.isa().name()))
                    .field("panel_nr", Json::num(kr.nr() as f64))
                    .field("k", Json::num(k as f64))
                    .field("m", Json::num(m as f64))
                    .field("batch", Json::num(batch as f64))
                    .field("faulty_macs", Json::num(faults as f64))
                    .field("mitigation", Json::str(label))
                    .field("macs", Json::num(macs as f64))
                    .field("scalar", scalar.to_json())
                    .field("simd", packed.to_json())
                    .field("scalar_macs_per_s", Json::num(scalar.throughput(macs)))
                    .field("simd_macs_per_s", Json::num(packed.throughput(macs)))
                    .field("speedup_simd", Json::num(speedup_simd)),
            );

            // i8 vs i32 panels at the dispatched width: isolates the
            // narrow-panel (memory traffic) win from the lane-count win
            let plan_i32 = MatmulPlan::compile_opts(
                &fm,
                kind,
                &w,
                k,
                m,
                PanelOptions { nr: kr.nr(), allow_i8: false },
            );
            let mut out_i32 = vec![0i32; batch * m];
            let wide = bench::bench(&format!("i32 panels {k}x{m} ({label})"), wu, it, || {
                plan_i32.execute_into(&a, batch, &mut out_i32);
                bench::black_box(&mut out_i32);
            });
            wide.report_throughput(macs, "MAC");
            anyhow::ensure!(
                out_i32 == out_packed,
                "parity: i32 panels != i8 panels at {k}x{m} ({label})"
            );
            let speedup_i8 = wide.median.as_secs_f64() / packed.median.as_secs_f64().max(1e-12);
            println!("  -> i8-panel speedup over i32 panels = {speedup_i8:.2}");
            rows.push(
                Json::obj()
                    .field("row", Json::str("i8_vs_i32_panel"))
                    .field("isa", Json::str(kr.isa().name()))
                    .field("panel_nr", Json::num(kr.nr() as f64))
                    .field("k", Json::num(k as f64))
                    .field("m", Json::num(m as f64))
                    .field("batch", Json::num(batch as f64))
                    .field("faulty_macs", Json::num(faults as f64))
                    .field("mitigation", Json::str(label))
                    .field("macs", Json::num(macs as f64))
                    .field("i32_panel", wide.to_json())
                    .field("i8_panel", packed.to_json())
                    .field("i32_panel_macs_per_s", Json::num(wide.throughput(macs)))
                    .field("i8_panel_macs_per_s", Json::num(packed.throughput(macs)))
                    .field("speedup_i8", Json::num(speedup_i8)),
            );
        }
    }

    // observability overhead: the exec hot path is instrumented with a
    // relaxed-atomic enabled check (repro::obs); this row proves that even
    // with recording ON the simd_vs_scalar shape pays <2% (so the disabled
    // default, which only pays the check, is strictly cheaper). Gated like
    // the parity checks: a regression exits nonzero.
    {
        let (k, m) = *shapes.last().unwrap();
        let a: Vec<i32> = (0..batch * k).map(|_| rng.below(255) as i32 - 127).collect();
        let w: Vec<i32> = (0..k * m).map(|_| rng.below(255) as i32 - 127).collect();
        let plan =
            MatmulPlan::compile(&FaultMap::healthy(n), MaskKind::Unmitigated, &w, k, m);
        let (wu3, it3) = if quick { (2, 9) } else { (3, 21) };
        println!("# obs: instrumentation overhead on the {k}x{m} hot path");
        let mut out_dis = vec![0i32; batch * m];
        let dis = bench::bench(&format!("obs off {k}x{m} (batch {batch})"), wu3, it3, || {
            plan.execute_into(&a, batch, &mut out_dis);
            bench::black_box(&mut out_dis);
        });
        dis.report_throughput(timing::mac_ops(batch, k, m), "MAC");
        repro::obs::set_enabled(true);
        let mut out_en = vec![0i32; batch * m];
        let en = bench::bench(&format!("obs on  {k}x{m} (batch {batch})"), wu3, it3, || {
            plan.execute_into(&a, batch, &mut out_en);
            bench::black_box(&mut out_en);
        });
        repro::obs::set_enabled(false);
        repro::obs::reset_metrics();
        en.report_throughput(timing::mac_ops(batch, k, m), "MAC");
        anyhow::ensure!(out_en == out_dis, "parity: obs-on != obs-off at {k}x{m}");
        let overhead = en.min.as_secs_f64() / dis.min.as_secs_f64().max(1e-12) - 1.0;
        println!("  -> obs-enabled overhead = {:.2}%", overhead * 100.0);
        // 2% relative gate with a small absolute floor so timer jitter on
        // sub-100us shapes cannot flake the smoke run
        anyhow::ensure!(
            overhead < 0.02
                || en.min.saturating_sub(dis.min) < std::time::Duration::from_micros(2),
            "obs instrumentation overhead {:.2}% exceeds the 2% gate \
             (off {:?} vs on {:?})",
            overhead * 100.0,
            dis.min,
            en.min
        );
        rows.push(
            Json::obj()
                .field("row", Json::str("obs_overhead"))
                .field("k", Json::num(k as f64))
                .field("m", Json::num(m as f64))
                .field("batch", Json::num(batch as f64))
                .field("disabled", dis.to_json())
                .field("enabled", en.to_json())
                .field("overhead_frac", Json::num(overhead)),
        );
    }

    // pool vs scope: dispatch overhead at serving batch sizes, where
    // per-call thread spawns dominate small forwards
    let threads = default_threads().max(2);
    let pool = WorkerPool::new(threads);
    let (k, m) = if quick { (64usize, 64usize) } else { (256, 256) };
    let serving_batches: &[usize] = if quick { &[2, 8] } else { &[4, 64] };
    let (wu2, it2) = if quick { (1, 5) } else { (3, 30) };
    println!("# dispatch: spawn-once pool vs per-call thread::scope ({k}x{m}, x{threads})");
    let w: Vec<i32> = (0..k * m).map(|_| rng.below(255) as i32 - 127).collect();
    let plan = MatmulPlan::compile(&FaultMap::healthy(n), MaskKind::Unmitigated, &w, k, m);
    for &sb in serving_batches {
        let a: Vec<i32> = (0..sb * k).map(|_| rng.below(255) as i32 - 127).collect();
        let macs = timing::mac_ops(sb, k, m);
        let mut out_scope = vec![0i32; sb * m];
        let scope = bench::bench(&format!("scope x{threads} (batch {sb})"), wu2, it2, || {
            plan.execute_threaded_into(&a, sb, threads, &mut out_scope);
            bench::black_box(&mut out_scope);
        });
        scope.report_throughput(macs, "MAC");
        let mut out_pool = vec![0i32; sb * m];
        let pooled = bench::bench(&format!("pool  x{threads} (batch {sb})"), wu2, it2, || {
            plan.execute_pooled_into(&a, sb, &pool, &mut out_pool);
            bench::black_box(&mut out_pool);
        });
        pooled.report_throughput(macs, "MAC");
        anyhow::ensure!(out_pool == out_scope, "parity: pool != scope at batch {sb}");
        let speedup = scope.median.as_secs_f64() / pooled.median.as_secs_f64().max(1e-12);
        println!("  -> pool speedup over scope = {speedup:.2} (batch {sb})");
        rows.push(
            Json::obj()
                .field("row", Json::str("pool_vs_scope"))
                .field("k", Json::num(k as f64))
                .field("m", Json::num(m as f64))
                .field("batch", Json::num(sb as f64))
                .field("threads", Json::num(threads as f64))
                .field("macs", Json::num(macs as f64))
                .field("scope", scope.to_json())
                .field("pool", pooled.to_json())
                .field("scope_macs_per_s", Json::num(scope.throughput(macs)))
                .field("pool_macs_per_s", Json::num(pooled.throughput(macs)))
                .field("speedup_pool", Json::num(speedup)),
        );
    }

    let meta = Json::obj()
        .field("array_n", Json::num(n as f64))
        .field("batch", Json::num(batch as f64))
        .field("threads", Json::num(threads as f64))
        .field("simd_isa", Json::str(kr.isa().name()))
        .field("panel_nr", Json::num(kr.nr() as f64))
        .field("quick", Json::num(if quick { 1.0 } else { 0.0 }));
    Ok((meta, rows))
}

/// End-to-end `ChipSession` forward passes, one row per backend (`sim`,
/// `plan`, and `xla` when an artifacts directory is present): the mnist
/// MLP on a 10%-faulty 64×64 chip under FAP bypass. Also returns the
/// engines' aggregated plan-cache stats `(live, hits, misses, evictions)`
/// for the `BENCH_exec.json` meta.
fn bench_backend_sessions(
    rng: &mut Rng,
    quick: bool,
) -> anyhow::Result<(Vec<Json>, (usize, usize, usize, usize))> {
    let (array_n, faults, batch) = if quick { (32usize, 102, 16) } else { (64, 410, 64) };
    println!("\n# chip-session backends (mnist, {array_n}x{array_n}, 10% faults, FAP bypass)");
    let a = arch::by_name("mnist").unwrap();
    let mut params = Params::zeros_like(&a);
    for (w, b) in &mut params.layers {
        w.iter_mut().for_each(|v| *v = rng.normal() * 0.05);
        b.iter_mut().for_each(|v| *v = rng.normal() * 0.01);
    }
    let x: Vec<f32> = (0..batch * a.input_len()).map(|_| rng.normal()).collect();
    let calib = calibrate_mlp(&a, &params, &x, batch);
    let chip =
        Chip::new(a.clone()).array_n(array_n).inject(faults, 13).mitigate(MaskKind::FapBypass);
    let macs: u64 = a.weighted_layers().iter().map(|l| (batch * l.weight_len()) as u64).sum();

    let rt = Runtime::new("artifacts").ok();
    let mut rows = Vec::new();
    let mut cache = (0usize, 0usize, 0usize, 0usize);
    for backend in [Backend::Sim, Backend::Plan, Backend::Xla] {
        if backend == Backend::Xla && rt.is_none() {
            println!("(skipping xla backend row: no artifacts)");
            continue;
        }
        let mut engine = Engine::new(backend, rt.as_ref())?;
        let mut sess = engine.session(&chip)?;
        sess.load_model(params.clone(), calib.clone());
        // the sim walks PE chains per call: keep its iteration count low
        let (warmup, iters) = match (backend, quick) {
            (Backend::Sim, false) => (1, 3),
            (Backend::Sim, true) => (0, 1),
            (_, false) => (2, 10),
            (_, true) => (1, 3),
        };
        let r = bench::bench(
            &format!("session fwd ({} backend, batch {batch})", backend.name()),
            warmup,
            iters,
            || {
                bench::black_box(sess.forward_logits(&x, batch).unwrap());
            },
        );
        r.report_throughput(macs, "MAC");
        // session rows carry their own shape: they run a 64x64 mnist chip,
        // not the exec sweep's 256x256 / 512x512 geometry in the file meta
        rows.push(
            Json::obj()
                .field("backend", Json::str(backend.name()))
                .field("model", Json::str("mnist"))
                .field("array_n", Json::num(array_n as f64))
                .field("faulty_macs", Json::num(faults as f64))
                .field("batch", Json::num(batch as f64))
                .field("macs", Json::num(macs as f64))
                .field("session_fwd", r.to_json())
                .field("macs_per_s", Json::num(r.throughput(macs))),
        );
        let (live, hits, misses, evictions) = engine.plan_stats();
        cache = (cache.0 + live, cache.1 + hits, cache.2 + misses, cache.3 + evictions);
    }
    Ok((rows, cache))
}

/// One open-loop serving row: knobs + every headline serving statistic.
fn open_row(mode: &str, cfg: &OpenWorkloadConfig, o: &OpenLoopStats) -> Json {
    Json::obj()
        .field("mode", Json::str(mode))
        .field("arrival", Json::str(cfg.arrival.name()))
        .field("batch_max", Json::num(cfg.batcher.batch_max as f64))
        .field("batch_age_us", Json::num(cfg.batcher.max_batch_age_us))
        .field("queue_timeout_us", Json::num(cfg.batcher.queue_timeout_us))
        .field("offered", Json::num(o.offered as f64))
        .field("served", Json::num(o.served as f64))
        .field("shed", Json::num(o.shed as f64))
        .field("timed_out", Json::num(o.timed_out as f64))
        .field("offered_load_rps", Json::num(o.offered_load_rps()))
        .field("goodput_rps", Json::num(o.goodput_rps()))
        .field("mean_batch_fill", Json::num(o.mean_batch_fill()))
        .field("p50_latency_us", Json::num(o.p50_latency_us()))
        .field("p99_latency_us", Json::num(o.p99_latency_us()))
        .field("p999_latency_us", Json::num(o.p999_latency_us()))
}

/// Fleet serving benchmarks, emitted as `BENCH_fleet.json` so the
/// serving-layer perf trajectory is tracked PR over PR like the exec
/// engine's. Three row families over the same 4x 32x32 faulty-chip fleet:
///
/// * `closed`: the closed-loop batched dispatcher, one row per routing
///   policy (wall samples/s + latency percentiles);
/// * `open`: open-loop arrival streams (Poisson + bursty MMPP) through
///   the dynamic batcher — virtual-clock DES only, at millions of
///   requests in the full run — plus one executed `open_exec` row for
///   wall-clock samples/s and served accuracy;
/// * `sweep`: the batching-window sweep at one offered load, fixed-batch
///   (age = inf) against dynamic windows. **Goodput-gated**: the bench
///   exits nonzero if any dynamic window fails to beat fixed-batch
///   serving on both served count and goodput.
fn bench_fleet_scheduler(rng: &mut Rng, quick: bool) -> anyhow::Result<(Json, Vec<Json>)> {
    println!("\n# fleet scheduler (mnist, 4x 32x32 chips, 5% faults, FAP bypass)");
    let a = arch::by_name("mnist").unwrap();
    let (chips_n, array_n) = (4usize, 32usize);
    let (batch, requests) = if quick { (16usize, 8usize) } else { (64, 32) };
    // the DES costs no forwards, so the full bench offers millions of
    // requests per open-loop row; the executed row stays moderate
    let (open_offered, exec_offered) =
        if quick { (20_000usize, 512usize) } else { (2_000_000, 8_192) };
    // +8 keeps every chip's round-robin share from dividing batch_max, so
    // fixed-batch mode provably strands a tail partial window per chip
    let sweep_offered = open_offered + 8;
    let sweep_rate = 2.0e5;
    let mut params = Params::zeros_like(&a);
    for (w, b) in &mut params.layers {
        w.iter_mut().for_each(|v| *v = rng.normal() * 0.05);
        b.iter_mut().for_each(|v| *v = rng.normal() * 0.01);
    }
    let (_, workload) = data::for_arch("mnist", 64, 512, 53).unwrap();
    let calib = calibrate_mlp(&a, &params, &workload.x[..64 * a.input_len()], 64);
    let chips: Vec<Chip> = (0..chips_n)
        .map(|i| {
            Chip::new(a.clone())
                .array_n(array_n)
                .inject(array_n * array_n / 20, 400 + i as u64)
                .mitigate(MaskKind::FapBypass)
                .threads(1)
        })
        .collect();
    let units: Vec<ChipUnit<'_>> = chips
        .iter()
        .enumerate()
        .map(|(i, c)| ChipUnit { id: i, chip: c, params: &params, weight: 1.0 - 0.1 * i as f64 })
        .collect();

    // ---- closed loop: one row per routing policy ------------------------
    let mut rows = Vec::new();
    for policy in
        [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded, RoutingPolicy::AccuracyWeighted]
    {
        let cfg = WorkloadConfig {
            backend: Backend::Plan,
            policy,
            batch,
            queue_depth: 4,
            requests,
            workers: 0,
            seed: 71,
        };
        let rep = serve(&units, &calib, &workload, &cfg)?;
        let lats = rep.sorted_latencies_us();
        let (p50, p99) = (percentile(&lats, 0.5), percentile(&lats, 0.99));
        println!(
            "fleet closed {policy:<18} {:>10.0} samples/s  p50 {p50:>8.0}us  p99 {p99:>8.0}us",
            rep.samples_per_sec()
        );
        rows.push(
            Json::obj()
                .field("mode", Json::str("closed"))
                .field("policy", Json::str(policy.name()))
                .field("chips", Json::num(chips_n as f64))
                .field("array_n", Json::num(array_n as f64))
                .field("batch", Json::num(batch as f64))
                .field("requests", Json::num(requests as f64))
                .field("samples", Json::num(rep.samples as f64))
                .field("samples_per_sec", Json::num(rep.samples_per_sec()))
                .field("sim_cycles", Json::num(rep.sim_cycles as f64))
                .field("p50_latency_us", Json::num(p50))
                .field("p99_latency_us", Json::num(p99)),
        );
    }

    let mk_open = |arrival, rate_rps, offered, age_us, execute| OpenWorkloadConfig {
        backend: Backend::Plan,
        policy: RoutingPolicy::RoundRobin,
        arrival,
        rate_rps,
        offered,
        batcher: BatcherConfig {
            batch_max: batch,
            max_batch_age_us: age_us,
            queue_timeout_us: 5_000.0,
            queue_depth: 4,
        },
        workers: 0,
        execute,
        seed: 71,
    };

    // ---- open loop: Poisson + bursty DES rows at auto (~70%) load -------
    for arrival in [ArrivalProcess::Poisson, ArrivalProcess::Bursty] {
        let cfg = mk_open(arrival, 0.0, open_offered, 200.0, false);
        let rep = serve_open(&units, &calib, &workload, &cfg)?;
        let o = rep.open.as_ref().unwrap();
        anyhow::ensure!(o.conservation_ok(), "open-loop conservation violated ({})", arrival);
        println!(
            "fleet open {:<7} offered {:>8} served {:>8} shed {:>6} timeout {:>6}  \
             goodput {:>9.0} rps  fill {:>3.0}%",
            arrival.name(),
            o.offered,
            o.served,
            o.shed,
            o.timed_out,
            o.goodput_rps(),
            o.mean_batch_fill() * 100.0
        );
        rows.push(open_row("open", &cfg, o));
    }

    // ---- open loop, executed: wall samples/s + served accuracy ----------
    let cfg = mk_open(ArrivalProcess::Poisson, 0.0, exec_offered, 200.0, true);
    let rep = serve_open(&units, &calib, &workload, &cfg)?;
    let o = rep.open.as_ref().unwrap();
    println!(
        "fleet open executed: {} served at {:>8.0} samples/s wall, accuracy {:.2}%",
        rep.requests,
        rep.samples_per_sec(),
        rep.accuracy() * 100.0
    );
    rows.push(
        open_row("open_exec", &cfg, o)
            .field("samples", Json::num(rep.samples as f64))
            .field("accuracy", Json::num(rep.accuracy()))
            .field("samples_per_sec", Json::num(rep.samples_per_sec())),
    );

    // ---- batching-window sweep: fixed-batch vs dynamic, same load -------
    let (mut fixed_served, mut fixed_goodput) = (0usize, 0.0f64);
    for age_us in [f64::INFINITY, 50.0, 200.0, 1000.0] {
        let cfg = mk_open(ArrivalProcess::Poisson, sweep_rate, sweep_offered, age_us, false);
        let rep = serve_open(&units, &calib, &workload, &cfg)?;
        let o = rep.open.as_ref().unwrap();
        anyhow::ensure!(o.conservation_ok(), "open-loop conservation violated (window sweep)");
        let window = if age_us.is_finite() { format!("{age_us:.0}us") } else { "fixed".into() };
        println!(
            "fleet window {:<6} served {:>8}/{:>8} timeout {:>5}  goodput {:>9.0} rps  \
             fill {:>3.0}%",
            window,
            o.served,
            o.offered,
            o.timed_out,
            o.goodput_rps(),
            o.mean_batch_fill() * 100.0
        );
        if age_us.is_infinite() {
            (fixed_served, fixed_goodput) = (o.served, o.goodput_rps());
        } else {
            anyhow::ensure!(
                o.served > fixed_served && o.goodput_rps() > fixed_goodput,
                "dynamic batching (age {window}) must beat fixed-batch serving: served {} vs \
                 {fixed_served}, goodput {:.0} vs {fixed_goodput:.0} rps",
                o.served,
                o.goodput_rps()
            );
        }
        rows.push(open_row("sweep", &cfg, o).field("window", Json::str(window)));
    }

    let meta = Json::obj()
        .field("model", Json::str("mnist"))
        .field("chips", Json::num(chips_n as f64))
        .field("array_n", Json::num(array_n as f64))
        .field("batch", Json::num(batch as f64))
        .field("requests", Json::num(requests as f64))
        .field("open_offered", Json::num(open_offered as f64))
        .field("exec_offered", Json::num(exec_offered as f64))
        .field("sweep_offered", Json::num(sweep_offered as f64))
        .field("sweep_rate_rps", Json::num(sweep_rate));
    Ok((meta, rows))
}

/// Bit pattern of every parameter, layer order — the train parity gates
/// compare these, so "bit-identical" means exactly that.
fn params_bits(p: &Params) -> Vec<u32> {
    p.layers.iter().flat_map(|(w, b)| w.iter().chain(b).map(|v| v.to_bits())).collect()
}

/// The native training engine: the packed-panel f32 SIMD step vs the
/// naive seed scalar step, single-thread and pooled (steps/s + samples/s,
/// emitted as `BENCH_train.json`), plus `retrain_wall_minutes` rows that
/// run the Fig 5 FAP+T campaign shape and record measured wall minutes
/// against the paper's 12-minute retraining budget.
///
/// **Parity-gated** bit-for-bit: trained parameters and losses must be
/// identical across the dispatched ISA, the runtime-width scalar
/// reference, the nr=4 scalar fallback, and 1/2/N pool lanes — a mismatch
/// exits nonzero (the CI smoke runs this under both `REPRO_SIMD` legs).
/// The ≥4× speedup floor over the naive step is asserted in full runs on
/// SIMD hosts only; timing is never gated in the quick smoke.
fn bench_train(rng: &mut Rng, quick: bool) -> anyhow::Result<(Json, Vec<Json>)> {
    // quick shrinks the arch like the other sections shrink their shapes;
    // the full run times the real fig2a mnist MLP at its train batch
    let a = if quick {
        Arch {
            name: "mnist-quick",
            layers: vec![Layer::fc(96, 64, true), Layer::fc(64, 10, false)],
            input_shape: vec![96],
            num_classes: 10,
            eval_batch: 32,
            train_batch: 32,
        }
    } else {
        arch::by_name("mnist").unwrap()
    };
    let b = a.train_batch;
    let (wu, it) = if quick { (1, 3) } else { (2, 10) };
    let kr = *kernel();
    let threads = default_threads().max(4);
    let pool = WorkerPool::new(threads);
    println!(
        "\n# train engine: f32 packed-panel SIMD ({} x{}) vs naive scalar ({}, batch {b})",
        kr.isa().name(),
        kr.nr(),
        a.name
    );

    // one fixed batch: sampling stays outside the timed region
    let x: Vec<f32> = (0..b * a.input_len()).map(|_| rng.normal().abs()).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.below(a.num_classes) as i32).collect();

    let mut rows = Vec::new();
    let mut state = NativeTrainState::init(&a, 11);
    let naive = bench::bench(&format!("naive scalar step (batch {b})"), wu, it, || {
        bench::black_box(native_train_step(&a, &mut state, None, &x, &y, b, 0.01));
    });
    naive.report_throughput(b as u64, "sample");

    let mut scratch = TrainScratch::new(&a, b);
    let mut state = NativeTrainState::init(&a, 11);
    let single = bench::bench(&format!("simd step x1 (batch {b})"), wu, it, || {
        bench::black_box(native_train_step_fast(
            &a, &mut state, None, &x, &y, 0.01, &mut scratch, None,
        ));
    });
    single.report_throughput(b as u64, "sample");

    let mut state = NativeTrainState::init(&a, 11);
    let pooled = bench::bench(&format!("simd step x{threads} pooled (batch {b})"), wu, it, || {
        bench::black_box(native_train_step_fast(
            &a,
            &mut state,
            None,
            &x,
            &y,
            0.01,
            &mut scratch,
            Some(&pool),
        ));
    });
    pooled.report_throughput(b as u64, "sample");

    let speedup_single = naive.median.as_secs_f64() / single.median.as_secs_f64().max(1e-12);
    let speedup_pooled = naive.median.as_secs_f64() / pooled.median.as_secs_f64().max(1e-12);
    println!("  -> step speedup over naive: x1={speedup_single:.2} x{threads}={speedup_pooled:.2}");
    // the acceptance floor: the SIMD trainer must be >=4x the seed scalar
    // step at the paper shapes. Timing gates stay out of the CI smoke, and
    // a scalar-forced run (REPRO_SIMD=scalar) measures the packing win
    // alone, so the floor applies to full runs on SIMD hosts only.
    if !quick && kr.isa() != Isa::Scalar {
        anyhow::ensure!(
            speedup_single.max(speedup_pooled) >= 4.0,
            "SIMD trainer must be >=4x the naive scalar step \
             (got x1={speedup_single:.2}, x{threads}={speedup_pooled:.2})"
        );
    }
    rows.push(
        Json::obj()
            .field("row", Json::str("step_throughput"))
            .field("model", Json::str(a.name))
            .field("isa", Json::str(kr.isa().name()))
            .field("panel_nr", Json::num(kr.nr() as f64))
            .field("batch", Json::num(b as f64))
            .field("threads", Json::num(threads as f64))
            .field("naive", naive.to_json())
            .field("simd_single", single.to_json())
            .field("simd_pooled", pooled.to_json())
            .field("naive_steps_per_s", Json::num(1.0 / naive.median.as_secs_f64().max(1e-12)))
            .field(
                "simd_single_steps_per_s",
                Json::num(1.0 / single.median.as_secs_f64().max(1e-12)),
            )
            .field(
                "simd_pooled_steps_per_s",
                Json::num(1.0 / pooled.median.as_secs_f64().max(1e-12)),
            )
            .field("naive_samples_per_s", Json::num(naive.throughput(b as u64)))
            .field("simd_single_samples_per_s", Json::num(single.throughput(b as u64)))
            .field("simd_pooled_samples_per_s", Json::num(pooled.throughput(b as u64)))
            .field("speedup_single", Json::num(speedup_single))
            .field("speedup_pooled", Json::num(speedup_pooled)),
    );

    // ---- parity: pool lane count must not change a single bit ----------
    let n_train = 4 * b;
    let ds = {
        let x: Vec<f32> = (0..n_train * a.input_len()).map(|_| rng.normal().abs()).collect();
        let y: Vec<i32> = (0..n_train).map(|_| rng.below(a.num_classes) as i32).collect();
        Dataset::new(x, y, a.input_len(), a.num_classes)
    };
    let cfg = TrainConfig {
        steps: if quick { 4 } else { 12 },
        lr: 0.05,
        end_lr_frac: 0.5,
        seed: 29,
        log_every: 0,
    };
    let pool2 = WorkerPool::new(2);
    let mut lane_runs = Vec::new();
    for (label, p) in [("x1", None), ("x2", Some(&pool2)), ("xN", Some(&pool))] {
        let mut st = NativeTrainState::init(&a, cfg.seed);
        let losses = run_steps_native_pooled(&a, &mut st, None, &ds, &cfg, p)?;
        lane_runs.push((label, st.params, losses));
    }
    for (label, p, losses) in &lane_runs[1..] {
        anyhow::ensure!(
            params_bits(p) == params_bits(&lane_runs[0].1),
            "parity: {label}-lane trained params != single-thread"
        );
        anyhow::ensure!(
            losses.iter().map(|v| v.to_bits()).eq(lane_runs[0].2.iter().map(|v| v.to_bits())),
            "parity: {label}-lane loss curve != single-thread"
        );
    }

    // ---- parity: dispatched ISA vs scalar kernels, same bits -----------
    let step_n = if quick { 3 } else { 8 };
    let mut kernel_runs = Vec::new();
    for (label, k) in [
        ("dispatched", kr),
        ("scalar-ref", Kernel::scalar_reference(kr.nr())),
        ("scalar-4", Kernel::scalar_fallback()),
    ] {
        let mut st = NativeTrainState::init(&a, 31);
        let mut sc = TrainScratch::with_kernel(&a, b, k);
        for _ in 0..step_n {
            native_train_step_fast(&a, &mut st, None, &x, &y, 0.02, &mut sc, None);
        }
        kernel_runs.push((label, st.params));
    }
    for (label, p) in &kernel_runs[1..] {
        anyhow::ensure!(
            params_bits(p) == params_bits(&kernel_runs[0].1),
            "parity: {label} kernel trained params != dispatched"
        );
    }
    println!(
        "  parity OK: 1/2/{threads} lanes and dispatched/scalar-ref/scalar-4 kernels \
         train bit-identical params"
    );

    // ---- retrain wall minutes: the Fig 5 campaign shape, measured ------
    let models: &[&str] = if quick { &["mnist"] } else { &["mnist", "timit"] };
    for &name in models {
        let ra = arch::by_name(name).unwrap();
        let samples = if quick { 2 * ra.train_batch } else { 1024 };
        let (train_ds, _) = data::for_arch(name, samples, 64, 8).unwrap();
        // the Fig 5 prune density stand-in: every 16th weight pruned
        let masks: Vec<Vec<f32>> = ra
            .weighted_layers()
            .iter()
            .map(|l| (0..l.weight_len()).map(|i| if i % 16 == 0 { 0.0 } else { 1.0 }).collect())
            .collect();
        let mut fap = he_init(&ra, 8);
        fap.apply_masks(&masks);
        let fcfg = FaptConfig {
            max_epochs: if quick { 1 } else { 2 },
            lr: 0.01,
            seed: 8,
            snapshot_epochs: vec![],
        };
        let res = fapt_retrain_native_pooled(&ra, &fap, &masks, &train_ds, &fcfg, Some(&pool))?;
        let minutes = res.wall_minutes();
        println!(
            "  retrain {name}: {} epochs x {} samples in {minutes:.3} min wall \
             ({:.2}s/epoch; paper budget 12 min)",
            res.epoch_losses.len(),
            train_ds.len(),
            res.secs_per_epoch
        );
        rows.push(
            Json::obj()
                .field("row", Json::str("retrain_wall_minutes"))
                .field("model", Json::str(name))
                .field("epochs", Json::num(res.epoch_losses.len() as f64))
                .field("train_samples", Json::num(train_ds.len() as f64))
                .field("threads", Json::num(threads as f64))
                .field("secs_per_epoch", Json::num(res.secs_per_epoch))
                .field("wall_minutes", Json::num(minutes))
                .field("paper_budget_minutes", Json::num(12.0)),
        );
    }

    let meta = Json::obj()
        .field("model", Json::str(a.name))
        .field("batch", Json::num(b as f64))
        .field("threads", Json::num(threads as f64))
        .field("simd_isa", Json::str(kr.isa().name()))
        .field("panel_nr", Json::num(kr.nr() as f64))
        .field("paper_budget_minutes", Json::num(12.0))
        .field("quick", Json::num(if quick { 1.0 } else { 0.0 }));
    Ok((meta, rows))
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var_os("REPRO_BENCH_QUICK").is_some();
    println!("## bench perf_hotpath{}\n", if quick { " (quick smoke)" } else { "" });
    let mut rng = Rng::new(51);

    // ---- exec engine: plan compiler + packed GEMM core (no PJRT needed)
    let (meta, mut results) = bench_exec_engine(&mut rng, quick)?;

    // ---- gemm kernel: microkernel-vs-dot + pool-vs-scope, parity-gated --
    let (gemm_meta, gemm_rows) = bench_gemm_micro(&mut rng, quick)?;
    bench::write_bench_json("BENCH_gemm.json", "gemm_microkernel", gemm_meta, gemm_rows)?;

    // ---- chip-session backends: one row per ForwardBackend (rows carry
    // their own shape fields; the file meta describes the exec sweep) ----
    let (session_rows, (pc_live, pc_hits, pc_misses, pc_evictions)) =
        bench_backend_sessions(&mut rng, quick)?;
    results.extend(session_rows);

    // plan-cache traffic of the session rows (one engine per backend,
    // aggregated) — the PR-over-PR record of cache effectiveness
    let meta = meta
        .field("plan_cache_live", Json::num(pc_live as f64))
        .field("plan_cache_hits", Json::num(pc_hits as f64))
        .field("plan_cache_misses", Json::num(pc_misses as f64))
        .field("plan_cache_evictions", Json::num(pc_evictions as f64));
    bench::write_bench_json("BENCH_exec.json", "exec_plan_vs_naive", meta, results)?;

    // ---- fleet scheduler: serving-layer rows, own bench record ----------
    let (fleet_meta, fleet_rows) = bench_fleet_scheduler(&mut rng, quick)?;
    bench::write_bench_json("BENCH_fleet.json", "fleet_scheduler", fleet_meta, fleet_rows)?;

    // ---- train engine: f32 SIMD trainer vs naive scalar, parity-gated ---
    let (train_meta, train_rows) = bench_train(&mut rng, quick)?;
    bench::write_bench_json("BENCH_train.json", "train_engine", train_meta, train_rows)?;

    if quick {
        // the smoke run exists to exercise the parity gates above; the
        // L3 / PJRT sections below add minutes without adding coverage
        println!("\n(quick mode: skipping L3 + PJRT sections)");
        return Ok(());
    }

    // ---- L3: cycle-level simulator hot loop -------------------------------
    println!("\n# L3 simulator");
    let n = 64;
    let (b, k, m) = (32, 512, 256);
    let fm = inject_uniform(FaultSpec::new(n), 200, &mut rng);
    let a: Vec<i32> = (0..b * k).map(|_| rng.below(255) as i32 - 127).collect();
    let w: Vec<i32> = (0..k * m).map(|_| rng.below(255) as i32 - 127).collect();
    let macs = timing::mac_ops(b, k, m);

    let mut tm = TiledMatmul::new(&FaultMap::healthy(n), false);
    let r = bench::bench("tiled matmul (healthy, 512x256 b32)", 2, 8, || {
        bench::black_box(tm.matmul(&a, &w, b, k, m));
    });
    r.report_throughput(macs, "MAC");

    let mut tmf = TiledMatmul::new(&fm, true);
    let r = bench::bench("tiled matmul (200 faults, FAP bypass)", 2, 8, || {
        bench::black_box(tmf.matmul(&a, &w, b, k, m));
    });
    r.report_throughput(macs, "MAC");

    // ---- L3: mask synthesis ------------------------------------------------
    println!("\n# L3 mask synthesis");
    let timit = arch::by_name("timit").unwrap();
    let fm256 = inject_uniform(FaultSpec::new(256), 16384, &mut rng);
    let r = bench::bench("LayerMasks::build(timit, 25% of 256x256)", 1, 5, || {
        bench::black_box(LayerMasks::build(&timit, &fm256, MaskKind::FapBypass));
    });
    let weights: usize = timit.weighted_layers().iter().map(|l| l.weight_len()).sum();
    r.report_throughput(weights as u64, "weight");

    // ---- RT: PJRT benches (need compiled artifacts) ------------------------
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("\n(skipping PJRT runtime benches: {e})");
            return Ok(());
        }
    };

    println!("\n# PJRT runtime");
    for name in ["mnist", "timit"] {
        let a = arch::by_name(name).unwrap();
        let exe = rt.load(&format!("{name}_fwd"))?;
        let init = rt.load(&format!("{name}_init"))?;
        let params = init.run(&[repro::runtime::scalar_i32(1)])?;
        let x: Vec<f32> = (0..a.eval_batch * a.input_len()).map(|_| rng.normal()).collect();
        let xlit = lit_f32(&x, &[a.eval_batch, a.input_len()])?;
        let mut inputs = params.clone();
        inputs.push(xlit);
        let r = bench::bench(&format!("{name}_fwd (batch {})", a.eval_batch), 2, 10, || {
            bench::black_box(exe.run(&inputs).unwrap());
        });
        r.report_throughput(a.eval_batch as u64, "samples");
    }

    // ---- RT: train step vs fused scan --------------------------------------
    println!("\n# train step vs fused {}-step scan (mnist)", 8);
    let a = arch::by_name("mnist").unwrap();
    let train_exe = rt.load("mnist_train")?;
    let masks = ones_masks(&a)?;
    let (ds, _) = data::for_arch("mnist", 128 * 9, 16, 52).unwrap();
    let x_dims = [a.train_batch, a.input_len()];

    let mut state = TrainState::init(&rt, &a, 1)?;
    let batch: Vec<f32> = ds.x[..a.train_batch * 784].to_vec();
    let ys: Vec<i32> = ds.y[..a.train_batch].to_vec();
    let r = bench::bench("mnist_train single step", 2, 10, || {
        bench::black_box(
            train_step(&train_exe, &mut state, &masks, &batch, &ys, &x_dims, 0.01).unwrap(),
        );
    });
    r.report_throughput(a.train_batch as u64, "samples");

    if rt.has("mnist_train_scan") {
        let scan_exe = rt.load("mnist_train_scan")?;
        let steps = scan_exe.spec.meta_usize("steps").unwrap_or(8);
        let state2 = TrainState::init(&rt, &a, 1)?;
        let xs: Vec<f32> = ds.x[..steps * a.train_batch * 784].to_vec();
        let ys: Vec<i32> = ds.y[..steps * a.train_batch].to_vec();
        let mut inputs: Vec<xla::Literal> = Vec::new();
        inputs.extend(state2.params.iter().cloned());
        inputs.extend(state2.vels.iter().cloned());
        inputs.extend(masks.iter().cloned());
        inputs.push(lit_f32(&xs, &[steps, a.train_batch, a.input_len()])?);
        inputs.push(lit_i32(&ys, &[steps, a.train_batch])?);
        inputs.push(scalar_f32(0.01));
        let r = bench::bench(&format!("mnist_train_scan ({steps} fused steps)"), 2, 10, || {
            bench::black_box(scan_exe.run(&inputs).unwrap());
        });
        r.report_throughput((steps * a.train_batch) as u64, "samples");
    }
    Ok(())
}
