//! Bench: Table 1 + §6.1 synthesis claims — per-model systolic schedule
//! cost (cycles, utilization, projected latency at the paper's 658 MHz)
//! and the synthesis/yield model tables.

use repro::model::{arch, Layer};
use repro::systolic::synthesis::{self, SynthesisModel};
use repro::systolic::timing;

fn main() {
    println!("## bench table1_synthesis\n");
    let m = SynthesisModel::paper_baseline();
    println!(
        "paper design point: {}x{} MACs @ {:.0} MHz, {:.1} W, {:.1} TOPS peak",
        m.n,
        m.n,
        m.freq_hz / 1e6,
        m.dynamic_power_w(),
        m.peak_tops()
    );
    println!(
        "FAP bypass area overhead: {:.0}% (paper: 9%)\n",
        (SynthesisModel::paper_fap().area_factor() - 1.0) * 100.0
    );

    println!(
        "{:<10} {:>6} {:>14} {:>12} {:>12} {:>10}",
        "model", "batch", "MAC ops", "cycles", "util %", "µs @658MHz"
    );
    for name in ["mnist", "timit", "alexnet32"] {
        let a = arch::by_name(name).unwrap();
        let batch = a.eval_batch;
        let n = 256;
        let (mut cycles, mut macs) = (0u64, 0u64);
        for l in a.weighted_layers() {
            match l {
                Layer::Fc(f) => {
                    cycles += timing::tiled_cycles(n, batch, f.din, f.dout);
                    macs += timing::mac_ops(batch, f.din, f.dout);
                }
                Layer::Conv(c) => {
                    // conv as the paper maps it: rows = input channels,
                    // cols = output channels, one pass per spatial output
                    // position per kernel tap
                    let positions = (32 * 32 / (c.stride * c.stride)) as u64;
                    let taps = (c.kh * c.kw) as u64;
                    cycles += timing::tiled_cycles(n, batch, c.din, c.dout)
                        * positions
                        * taps
                        / (n as u64) // row-reuse across taps amortized
                        ;
                    macs += batch as u64 * positions * taps * (c.din * c.dout) as u64;
                }
                Layer::Pool(_) => {}
            }
        }
        let util = macs as f64 / (cycles as f64 * (n * n) as f64);
        println!(
            "{:<10} {:>6} {:>14} {:>12} {:>12.2} {:>10.1}",
            name,
            batch,
            macs,
            cycles,
            util * 100.0,
            cycles as f64 / synthesis::PAPER_FREQ_HZ * 1e6
        );
    }

    println!("\n# yield model (motivation: discarding faulty chips kills yield)");
    println!("{:>14} {:>14} {:>12}", "defect rate", "discard yield", "FAP yield");
    for p in [1e-5, 1e-4, 1e-3, 0.01, 0.1, 0.25] {
        println!(
            "{:>13.3}% {:>13.2}% {:>11.2}%",
            p * 100.0,
            synthesis::yield_discard(256, p) * 100.0,
            synthesis::yield_fap(256, p, 0.5) * 100.0
        );
    }
}
