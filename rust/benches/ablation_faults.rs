//! Ablation bench: design choices DESIGN.md calls out for the fault model.
//!
//! 1. **Spatial distribution** — the paper samples faulty MACs uniformly;
//!    real defects cluster. How much does FAP's pruned-weight fraction
//!    (the quantity that drives accuracy) care?
//! 2. **Faults per MAC** — one stuck bit vs several per faulty MAC changes
//!    nothing for FAP (any fault ⇒ bypass) but changes unmitigated
//!    corruption strength.
//! 3. **Stuck-bit position** — high-order vs low-order stuck bits: the
//!    Fig 2b mechanism in isolation.

use repro::coordinator::baselines::ColumnBypass;
use repro::faults::aging::{AgingChip, AgingModel};
use repro::faults::{inject_clustered, inject_uniform, FaultMap, FaultSpec, StuckAt};
use repro::mapping::fc_prune_mask;
use repro::systolic::TiledMatmul;
use repro::util::Rng;

fn pruned_fraction(fm: &FaultMap, din: usize, dout: usize) -> f64 {
    let m = fc_prune_mask(fm, din, dout);
    m.iter().filter(|&&v| v == 0.0).count() as f64 / m.len() as f64
}

fn main() {
    println!("## bench ablation_faults\n");
    let n = 64;
    let spec = FaultSpec::new(n);

    println!("# 1. uniform vs clustered injection (FAP pruned fraction, 784x256 layer)");
    println!("{:>10} {:>12} {:>12}", "faulty %", "uniform", "clustered(r=3)");
    for rate in [0.05, 0.15, 0.30] {
        let k = (rate * (n * n) as f64) as usize;
        let (mut u_acc, mut c_acc) = (0.0, 0.0);
        let reps = 5;
        for rep in 0..reps {
            let mut rng = Rng::new(rep as u64 * 31 + (rate * 1e3) as u64);
            u_acc += pruned_fraction(&inject_uniform(spec, k, &mut rng), 784, 256);
            c_acc += pruned_fraction(&inject_clustered(spec, k, 3, &mut rng), 784, 256);
        }
        println!(
            "{:>9.1}% {:>11.2}% {:>11.2}%",
            rate * 100.0,
            u_acc / reps as f64 * 100.0,
            c_acc / reps as f64 * 100.0
        );
    }
    println!("(clustering leaves the pruned fraction ~unchanged — FAP is insensitive");
    println!(" to the spatial defect model, only the count matters)\n");

    println!("# 2. faults per MAC: unmitigated max |error| on a zero matmul");
    let mut rng = Rng::new(99);
    for fpm in [1usize, 2, 4] {
        let s = FaultSpec { n, faults_per_mac: fpm };
        let fm = inject_uniform(s, 64, &mut rng);
        let mut tm = TiledMatmul::new(&fm, false);
        let a = vec![0i32; 8 * n];
        let w = vec![0i32; n * n];
        let out = tm.matmul(&a, &w, 8, n, n);
        let maxabs = out.iter().map(|v| (*v as i64).abs()).max().unwrap();
        println!("  {fpm} fault(s)/MAC: max |acc| = {maxabs}");
    }

    println!("\n# 3. stuck-bit position vs corruption magnitude (single fault)");
    for bit in [2u8, 10, 18, 26, 30] {
        let fm = FaultMap::from_faults(
            n,
            [StuckAt { row: 5, col: 5, bit, value: true }],
        );
        let mut tm = TiledMatmul::new(&fm, false);
        let a = vec![1i32; n];
        let w = vec![1i32; n * n];
        let out = tm.matmul(&a, &w, 1, n, n);
        let err: i64 = out[5] as i64 - n as i64;
        println!("  stuck-at-1 bit {bit:>2}: output error {err:>12}");
    }
    println!("(error scales as 2^bit — the paper's Fig 2b mechanism)");

    println!("\n# 4. prior-work baseline (§2/§4): column bypass vs FAP");
    println!("   (256x256 array, timit fc1 1845x512, batch 256)");
    println!("{:>10} {:>14} {:>14} {:>12}", "faulty %", "healthy cols", "slowdown", "FAP slowdown");
    for rate in [0.001, 0.01, 0.05, 0.25] {
        let k = (rate * 65536.0) as usize;
        let fm = inject_uniform(FaultSpec::new(256), k, &mut Rng::new(7 + k as u64));
        let cb = ColumnBypass::from_map(&fm);
        let slow = cb
            .slowdown(256, 1845, 512)
            .map(|s| format!("{s:.1}x"))
            .unwrap_or_else(|| "unusable".into());
        println!(
            "{:>9.1}% {:>14} {:>14} {:>12}",
            rate * 100.0,
            cb.healthy_cols,
            slow,
            "1.0x" // FAP never shrinks the array
        );
    }
    println!("(the §4 argument: even at 1% faults nearly every column dies — FAP");
    println!(" keeps full throughput at every rate)");

    println!("\n# 5. aging faults (paper future work): lifetime fault accrual");
    let model = AgingModel {
        tau_hours: 100_000.0,
        beta: 2.0,
        spec: FaultSpec::new(256),
    };
    let mut chip = AgingChip::new(model, 30, 0xA6E);
    println!("{:>10} {:>14} {:>12}", "years", "faulty MACs", "fault rate");
    for _ in 0..6 {
        println!(
            "{:>10.1} {:>14} {:>11.2}%",
            chip.hours() / 8760.0,
            chip.fault_map().faulty_mac_count(),
            chip.fault_map().fault_rate() * 100.0
        );
        chip.advance(2.0 * 8760.0);
    }
    println!("(each re-provisioning step re-runs FAP+T on the grown map — the");
    println!(" fault maps are supersets, so masks only ever shrink)");
}
