//! Bench: Fig 2 regeneration cost + a small-scale rendition of the series.
//!
//! Times each stage of the unmitigated-fault evaluation pipeline (inject →
//! mask synthesis → quantized faulty eval) and prints a reduced Fig 2a
//! series so the bench doubles as a fast sanity check of the figure's
//! shape. Full-scale figures: `repro experiment --id fig2a`.

use repro::coordinator::evaluate::Evaluator;
use repro::coordinator::trainer::{train_baseline, TrainConfig};
use repro::data;
use repro::faults::{inject_uniform, FaultSpec};
use repro::mapping::{LayerMasks, MaskKind};
use repro::model::arch;
use repro::model::quant::calibrate_mlp;
use repro::runtime::Runtime;
use repro::util::bench;
use repro::util::Rng;

fn main() -> anyhow::Result<()> {
    println!("## bench fig2_baseline (MNIST unmitigated-fault pipeline)\n");
    let rt = Runtime::new("artifacts")?;
    let a = arch::by_name("mnist").unwrap();
    let (train, test) = data::for_arch("mnist", 1500, 512, 5).unwrap();
    let tcfg = TrainConfig { steps: 150, lr: 0.05, seed: 5, log_every: 0, ..Default::default() };
    let (params, _) = train_baseline(&rt, &a, &train, &tcfg)?;
    let calib = calibrate_mlp(&a, &params, &train.x[..64 * 784], 64);
    let ev = Evaluator::new(&rt);

    let n = 256;
    let mut rng = Rng::new(17);

    bench::run("inject_uniform(256x256, k=64)", 20, || {
        bench::black_box(inject_uniform(FaultSpec::new(n), 64, &mut rng));
    });

    let fm = inject_uniform(FaultSpec::new(n), 64, &mut Rng::new(17));
    bench::run("LayerMasks::build(mnist, unmitigated)", 10, || {
        bench::black_box(LayerMasks::build(&a, &fm, MaskKind::Unmitigated));
    });

    let masks = LayerMasks::build(&a, &fm, MaskKind::Unmitigated);
    let r = bench::bench("faulty eval (512 samples, quantized path)", 1, 3, || {
        bench::black_box(
            ev.accuracy_faulty(&a, &params, &masks, &calib, &test, false).unwrap(),
        );
    });
    r.report_throughput(test.len() as u64, "samples");

    println!("\n# reduced Fig 2a series (shape check)");
    for k in [0usize, 4, 16, 64] {
        let fm = inject_uniform(FaultSpec::new(n), k, &mut Rng::new(23 + k as u64));
        let masks = LayerMasks::build(&a, &fm, MaskKind::Unmitigated);
        let acc = ev.accuracy_faulty(&a, &params, &masks, &calib, &test, false)?;
        println!("  {k:>3} faulty MACs -> {:.2}%", acc * 100.0);
    }
    Ok(())
}
