//! Bench: Fig 4 regeneration cost — FAP mask synthesis / pruning and the
//! FAP+T retraining inner loop, plus a reduced rendition of the series.
//! Full-scale figures: `repro experiment --id fig4a` / `fig4b`.

use repro::coordinator::evaluate::Evaluator;
use repro::coordinator::fap::apply_fap;
use repro::coordinator::fapt::{fapt_retrain, FaptConfig};
use repro::coordinator::trainer::{train_baseline, TrainConfig};
use repro::data;
use repro::faults::{inject_uniform, FaultSpec};
use repro::model::arch;
use repro::runtime::Runtime;
use repro::util::bench;
use repro::util::Rng;

fn main() -> anyhow::Result<()> {
    println!("## bench fig4_fap_fapt (MNIST FAP / FAP+T pipeline)\n");
    let rt = Runtime::new("artifacts")?;
    let a = arch::by_name("mnist").unwrap();
    let (train, test) = data::for_arch("mnist", 1500, 512, 6).unwrap();
    let tcfg = TrainConfig { steps: 150, lr: 0.05, seed: 6, log_every: 0, ..Default::default() };
    let (baseline, _) = train_baseline(&rt, &a, &train, &tcfg)?;
    let ev = Evaluator::new(&rt);

    let n = 256;
    let fm = inject_uniform(FaultSpec::new(n), n * n / 4, &mut Rng::new(31));

    bench::run("apply_fap(mnist, 25% of 256x256)", 10, || {
        bench::black_box(apply_fap(&a, &baseline, &fm));
    });

    let (fap_params, masks, _) = apply_fap(&a, &baseline, &fm);
    let r = bench::bench("fapt_retrain (1 epoch, 1500 samples)", 1, 3, || {
        let cfg = FaptConfig { max_epochs: 1, lr: 0.01, seed: 6, snapshot_epochs: vec![] };
        bench::black_box(
            fapt_retrain(&rt, &a, &fap_params, &masks.prune, &train, &cfg).unwrap(),
        );
    });
    r.report_throughput(train.len() as u64, "samples");

    println!("\n# reduced Fig 4 series (shape check, mnist)");
    let base_acc = ev.accuracy(&a, &baseline, &test)?;
    println!("  baseline: {:.2}%", base_acc * 100.0);
    for rate in [0.25, 0.5] {
        let k = (rate * (n * n) as f64) as usize;
        let fm = inject_uniform(FaultSpec::new(n), k, &mut Rng::new(37 + k as u64));
        let (fp, masks, _) = apply_fap(&a, &baseline, &fm);
        let fap_acc = ev.accuracy(&a, &fp, &test)?;
        let cfg = FaptConfig { max_epochs: 2, lr: 0.01, seed: 6, snapshot_epochs: vec![] };
        let res = fapt_retrain(&rt, &a, &fp, &masks.prune, &train, &cfg)?;
        let fapt_acc = ev.accuracy(&a, &res.params, &test)?;
        println!(
            "  rate {:>4.1}%: FAP {:.2}%  FAP+T {:.2}%",
            rate * 100.0,
            fap_acc * 100.0,
            fapt_acc * 100.0
        );
    }
    Ok(())
}
