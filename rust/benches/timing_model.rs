//! Bench: the §3.2 timing claim (an N x N matmul of batch B takes 2N + B
//! cycles) — validated against the cycle-accurate simulator, plus the
//! simulator's own wall-clock cost at several array sizes.

use repro::faults::FaultMap;
use repro::systolic::{timing, SystolicArray};
use repro::util::bench;
use repro::util::Rng;

fn main() {
    println!("## bench timing_model\n");

    println!("# 2N+B validation (cycle-accurate sim vs paper formula)");
    println!("{:>6} {:>6} {:>12} {:>12} {:>8}", "N", "B", "sim cycles", "paper 2N+B", "delta");
    for (n, b) in [(8usize, 16usize), (16, 32), (32, 64), (64, 128)] {
        let arr = SystolicArray::healthy(n);
        let a = vec![1i32; n * b];
        let (_, cycles) = arr.matmul_cycle_accurate(&a, b, n, n);
        let paper = timing::paper_pass_cycles(n, b);
        println!(
            "{n:>6} {b:>6} {cycles:>12} {paper:>12} {:>8}",
            cycles as i64 - paper as i64
        );
    }

    println!("\n# simulator wall-clock (functional vs cycle-accurate)");
    let mut rng = Rng::new(3);
    for n in [16usize, 32, 64] {
        let b = 32;
        let mut arr = SystolicArray::with_faults(&FaultMap::healthy(n));
        let w: Vec<i32> = (0..n * n).map(|_| rng.below(255) as i32 - 127).collect();
        arr.load_weights(&w, n, n);
        let a: Vec<i32> = (0..b * n).map(|_| rng.below(255) as i32 - 127).collect();
        let macs = timing::mac_ops(b, n, n);

        let rf = bench::bench(&format!("functional {n}x{n} b{b}"), 2, 10, || {
            bench::black_box(arr.matmul(&a, b, n, n));
        });
        rf.report_throughput(macs, "MAC");
        let rc = bench::bench(&format!("cycle-accurate {n}x{n} b{b}"), 1, 3, || {
            bench::black_box(arr.matmul_cycle_accurate(&a, b, n, n));
        });
        rc.report_throughput(macs, "MAC");
    }

    println!("\n# utilization model across layer shapes (batch 256, N=256)");
    for (k, m) in [(784usize, 256usize), (1845, 512), (512, 512), (256, 10)] {
        println!(
            "  {k:>5} x {m:<5}: {:>5.1}% utilization, {} passes",
            timing::utilization(256, 256, k, m) * 100.0,
            timing::tile_passes(256, k, m)
        );
    }
}
