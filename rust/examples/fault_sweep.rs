//! Fault-sweep study (Fig 2a style) through the `ChipSession` API.
//!
//! Sweeps the number of faulty MACs on the physical array and reports the
//! unmitigated quantized accuracy of MNIST, demonstrating the paper's
//! motivating observation: a handful of faults among tens of thousands of
//! MACs destroys the model.
//!
//! ```text
//! cargo run --release --example fault_sweep [-- <array_n> [backend]]
//! ```
//!
//! Runs artifact-free on the `plan` backend by default; pass `sim` or
//! `xla` as the second argument to change engines.

use repro::chip::{Backend, Chip, Engine};
use repro::coordinator::trainer::TrainConfig;
use repro::data;
use repro::model::arch;
use repro::model::quant::calibrate_mlp;
use repro::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(256);
    let backend = Backend::parse(&std::env::args().nth(2).unwrap_or_else(|| "plan".into()))?;
    let rt = if backend == Backend::Xla { Some(Runtime::new("artifacts")?) } else { None };
    let mut engine = Engine::new(backend, rt.as_ref())?;

    let a = arch::by_name("mnist").unwrap();
    let (train, test) = data::for_arch("mnist", 2500, 600, 1).unwrap();
    let tcfg = TrainConfig { steps: 250, lr: 0.05, seed: 1, log_every: 0, ..Default::default() };
    let (params, _) = engine.train(&a, &train, &tcfg)?;
    let calib = calibrate_mlp(&a, &params, &train.x[..64 * 784], 64);
    let base = engine.float_accuracy(&a, &params, &test)?;
    println!(
        "array {n}x{n} ({} MACs), {} backend, float baseline {:.2}%\n",
        n * n,
        engine.backend(),
        base * 100.0
    );
    println!("{:>12} {:>12} {:>10}", "faulty MACs", "fault rate", "accuracy");

    for k in [0usize, 1, 2, 4, 8, 16, 32, 64, 128] {
        if k > n * n {
            break;
        }
        let mut accs = Vec::new();
        for rep in 0..3 {
            let chip = Chip::new(a.clone()).array_n(n).inject(k, 100 + k as u64 * 7 + rep);
            let mut sess = engine.session(&chip)?;
            sess.load_model(params.clone(), calib.clone());
            accs.push(sess.evaluate(&test)?);
            if k == 0 {
                break;
            }
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        println!(
            "{:>12} {:>11.4}% {:>9.2}%",
            k,
            k as f64 / (n * n) as f64 * 100.0,
            mean * 100.0
        );
    }
    Ok(())
}
