//! End-to-end driver: the life of one faulty TPU chip, through the
//! unified `Chip` / `ChipSession` API.
//!
//! ```text
//! cargo run --release --example chip_provisioning [-- <backend>]
//! ```
//!
//! This is the full-system workload (EXPERIMENTS.md §End-to-end),
//! artifact-free on the default `plan` backend:
//!
//! 1. **Train** the golden MNIST MLP from scratch on the procedural digit
//!    dataset, logging the loss curve.
//! 2. **Fabricate** a chip: a 64x64 systolic array with 15% permanent
//!    stuck-at faults (hidden from the controller).
//! 3. **Post-fab test**: `Chip::detect` localizes every faulty MAC with
//!    the DFT bypass binary search (no knowledge of the injected map).
//! 4. **FAP + FAP+T**: prune and retrain for this chip's detected map.
//! 5. **Deploy**: serve batched inference on the faulty chip's quantized
//!    datapath (bypass live) and report accuracy, latency and throughput.

use repro::chip::{Backend, Chip, Engine};
use repro::coordinator::fap::apply_fap_planned;
use repro::coordinator::fapt::FaptConfig;
use repro::coordinator::trainer::TrainConfig;
use repro::data;
use repro::mapping::MaskKind;
use repro::model::arch;
use repro::runtime::Runtime;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let backend = Backend::parse(&std::env::args().nth(1).unwrap_or_else(|| "plan".into()))?;
    let rt = if backend == Backend::Xla { Some(Runtime::new("artifacts")?) } else { None };
    let mut engine = Engine::new(backend, rt.as_ref())?;
    let a = arch::by_name("mnist").unwrap();

    // 1. golden training with loss-curve logging
    println!("=== 1. training golden model ({} backend) ===", engine.backend());
    let (train, test) = data::for_arch("mnist", 4000, 1000, 77).unwrap();
    let tcfg = TrainConfig { steps: 400, lr: 0.05, seed: 77, log_every: 50, ..Default::default() };
    let t0 = Instant::now();
    let (baseline, losses) = engine.train(&a, &train, &tcfg)?;
    let base_acc = engine.float_accuracy(&a, &baseline, &test)?;
    println!(
        "trained {} params in {:.1}s: loss {:.3} -> {:.4}, accuracy {:.2}%",
        a.param_count(),
        t0.elapsed().as_secs_f64(),
        losses[0],
        losses.last().unwrap(),
        base_acc * 100.0
    );

    // 2. the fab delivers a wounded chip
    println!("\n=== 2. chip arrives with hidden permanent faults ===");
    let n = 64;
    let chip = Chip::new(a.clone()).array_n(n).inject((n * n) * 15 / 100, 0xFAB);
    println!(
        "(hidden truth: {} faulty MACs, {:.1}%)",
        chip.true_fault_map().faulty_mac_count(),
        chip.true_fault_map().fault_rate() * 100.0
    );

    // 3. post-fab test localizes them through the DFT interface only
    println!("\n=== 3. post-fabrication fault localization ===");
    let t0 = Instant::now();
    let chip = chip.detect()?.mitigate(MaskKind::FapBypass);
    let truth = chip.true_fault_map().faulty_macs();
    let correct =
        chip.known_map().faulty_macs().iter().filter(|f| truth.contains(f)).count();
    println!(
        "localized {} / {} faulty MACs ({:.1} ms)",
        correct,
        truth.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // 4. FAP + FAP+T for this chip's *detected* view — the truth map
    // keeps driving the datapath; the view only decides the masks
    println!("\n=== 4. FAP + FAP+T provisioning ===");
    let plan = engine.plans.get_or_compile_views(
        &a,
        chip.true_fault_map(),
        &chip.known_map(),
        MaskKind::FapBypass,
    );
    let (fap_params, frep) = apply_fap_planned(&baseline, &plan);
    let fap_acc = engine.float_accuracy(&a, &fap_params, &test)?;
    let fcfg = FaptConfig { max_epochs: 4, lr: 0.01, seed: 77, snapshot_epochs: vec![] };
    let res = engine.retrain(&a, &fap_params, &plan.masks().prune, &train, &fcfg)?;
    let fapt_acc = engine.float_accuracy(&a, &res.params, &test)?;
    println!(
        "pruned {} weights ({:.1}%); FAP {:.2}% -> FAP+T {:.2}% ({:.2}s/epoch)",
        frep.pruned_weights,
        frep.pruned_fraction() * 100.0,
        fap_acc * 100.0,
        fapt_acc * 100.0,
        res.secs_per_epoch
    );

    // 5. deploy: batched serving on the faulty chip's quantized datapath
    println!("\n=== 5. serving on the faulty chip (bypass live) ===");
    let mut session = engine.session(&chip)?;
    session.calibrate_and_load(res.params.clone(), &train.x[..64 * 784], 64);
    let t0 = Instant::now();
    let chip_acc = session.evaluate(&test)?;
    let elapsed = t0.elapsed();
    let batches = test.len().div_ceil(a.eval_batch);
    println!(
        "served {} samples in {} batches: accuracy {:.2}%, {:.1} ms/batch, {:.0} samples/s",
        test.len(),
        batches,
        chip_acc * 100.0,
        elapsed.as_secs_f64() * 1e3 / batches as f64,
        test.len() as f64 / elapsed.as_secs_f64()
    );
    println!(
        "\nsummary: golden {:.2}% | unmitigated chip would collapse | FAP {:.2}% | \
         FAP+T on-chip {:.2}%",
        base_acc * 100.0,
        fap_acc * 100.0,
        chip_acc * 100.0
    );
    Ok(())
}
