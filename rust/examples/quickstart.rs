//! Quickstart: the whole FAP / FAP+T story in ~60 lines — and, since the
//! `ChipSession` API, with **no artifacts directory needed**.
//!
//! ```text
//! cargo run --release --example quickstart [-- <backend>]
//! ```
//!
//! Trains the paper's MNIST MLP (784-256-256-256-10) on the procedural
//! digit dataset, breaks a 64x64 systolic array with 25% permanent
//! faults, and shows the accuracy of: no mitigation → FAP (prune) →
//! FAP+T (prune + retrain). `backend` is `plan` (default, native),
//! `sim` (cycle-level oracle) or `xla` (needs `artifacts/`).

use repro::chip::{Backend, Chip, Engine};
use repro::coordinator::fap::apply_fap_planned;
use repro::coordinator::fapt::FaptConfig;
use repro::coordinator::trainer::TrainConfig;
use repro::data;
use repro::mapping::MaskKind;
use repro::model::arch;
use repro::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. one engine for everything: training, float eval, chip sessions
    let backend = Backend::parse(&std::env::args().nth(1).unwrap_or_else(|| "plan".into()))?;
    let rt = if backend == Backend::Xla { Some(Runtime::new("artifacts")?) } else { None };
    let mut engine = Engine::new(backend, rt.as_ref())?;
    let a = arch::by_name("mnist").unwrap();

    // 2. data + baseline training (host-native unless --backend xla)
    let (train, test) = data::for_arch("mnist", 3000, 800, 42).unwrap();
    let tcfg = TrainConfig { steps: 300, lr: 0.05, seed: 42, log_every: 100, ..Default::default() };
    let (baseline, _) = engine.train(&a, &train, &tcfg)?;
    let base_acc = engine.float_accuracy(&a, &baseline, &test)?;

    // 3. a chip comes back from the fab with 25% of its MACs broken
    let n = 64;
    let chip = Chip::new(a.clone()).array_n(n).inject(n * n / 4, 7);
    println!(
        "chip: {n}x{n} array, {} faulty MACs ({:.0}%), {} backend",
        chip.true_fault_map().faulty_mac_count(),
        chip.true_fault_map().fault_rate() * 100.0,
        engine.backend()
    );

    // 4. unmitigated: run the quantized faulty datapath as-is
    let mut faulty = engine.session(&chip)?;
    faulty.calibrate_and_load(baseline.clone(), &train.x[..64 * 784], 64);
    let faulty_acc = faulty.evaluate(&test)?;

    // 5. FAP: bypass faulty MACs == prune their weights (no localization
    // step here, so the controller has perfect knowledge of the truth map)
    let plan = engine.plans.get_or_compile(&a, chip.true_fault_map(), MaskKind::FapBypass);
    let (fap_params, report) = apply_fap_planned(&baseline, &plan);
    let fap_acc = engine.float_accuracy(&a, &fap_params, &test)?;

    // 6. FAP+T: Algorithm 1 — retrain the surviving weights
    let fcfg = FaptConfig { max_epochs: 3, lr: 0.01, seed: 42, snapshot_epochs: vec![] };
    let res = engine.retrain(&a, &fap_params, &plan.masks().prune, &train, &fcfg)?;
    let fapt_acc = engine.float_accuracy(&a, &res.params, &test)?;

    println!("\n  baseline (fault-free) : {:>6.2}%", base_acc * 100.0);
    println!("  unmitigated faults    : {:>6.2}%", faulty_acc * 100.0);
    println!("  FAP   ({:>6} pruned)  : {:>6.2}%", report.pruned_weights, fap_acc * 100.0);
    println!("  FAP+T ({} epochs)      : {:>6.2}%  ({:.1}s/epoch)",
        fcfg.max_epochs, fapt_acc * 100.0, res.secs_per_epoch);
    Ok(())
}
