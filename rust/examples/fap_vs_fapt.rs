//! FAP vs FAP+T across fault rates (Fig 4 style) on TIMIT — the paper's
//! headline result: FAP alone holds to ~25% faulty MACs, FAP+T holds to
//! 50% with close-to-baseline accuracy.
//!
//! ```text
//! cargo run --release --example fap_vs_fapt [-- <model> [backend]]
//! ```
//!
//! Runs artifact-free on the `plan` backend by default (native training
//! and retraining); `xla` uses the AOT graphs in `artifacts/`.

use repro::chip::{Backend, Chip, Engine};
use repro::coordinator::fap::apply_fap_planned;
use repro::coordinator::fapt::FaptConfig;
use repro::coordinator::trainer::TrainConfig;
use repro::data;
use repro::mapping::MaskKind;
use repro::model::arch;
use repro::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "timit".into());
    let backend = Backend::parse(&std::env::args().nth(2).unwrap_or_else(|| "plan".into()))?;
    let rt = if backend == Backend::Xla { Some(Runtime::new("artifacts")?) } else { None };
    let mut engine = Engine::new(backend, rt.as_ref())?;

    let a = arch::by_name(&model).expect("mnist | timit | alexnet32");
    let (train, test) = data::for_arch(&model, 183 * 16, 183 * 4, 3)
        .or_else(|| data::for_arch(&model, 2000, 500, 3))
        .unwrap();
    let tcfg = TrainConfig { steps: 500, lr: 0.04, seed: 3, log_every: 200, ..Default::default() };
    let (baseline, _) = engine.train(&a, &train, &tcfg)?;
    let base = engine.float_accuracy(&a, &baseline, &test)?;
    println!("\n{model} ({} backend): baseline accuracy {:.2}%\n", engine.backend(), base * 100.0);
    println!("{:>10} {:>10} {:>10} {:>10}", "fault %", "FAP %", "FAP+T %", "pruned %");

    let n = 256;
    for rate in [0.0625, 0.125, 0.25, 0.5] {
        let chip = Chip::new(a.clone())
            .array_n(n)
            .inject_rate(rate, 50 + (rate * 1e3) as u64)
            .mitigate(MaskKind::FapBypass);
        // one compiled plan per chip: FAP pruning and every retrain epoch
        // reuse its masks
        let plan = engine.plans.get_or_compile(&a, chip.true_fault_map(), MaskKind::FapBypass);
        let (fap_params, report) = apply_fap_planned(&baseline, &plan);
        let fap_acc = engine.float_accuracy(&a, &fap_params, &test)?;
        let fcfg = FaptConfig { max_epochs: 3, lr: 0.01, seed: 3, snapshot_epochs: vec![] };
        let res = engine.retrain(&a, &fap_params, &plan.masks().prune, &train, &fcfg)?;
        let fapt_acc = engine.float_accuracy(&a, &res.params, &test)?;
        println!(
            "{:>9.2}% {:>9.2}% {:>9.2}% {:>9.2}%",
            rate * 100.0,
            fap_acc * 100.0,
            fapt_acc * 100.0,
            report.pruned_fraction() * 100.0
        );
    }
    Ok(())
}
