//! Property tests: the packed-panel f32 training kernels and the pooled
//! minibatch trainer ([`repro::coordinator::trainer`]).
//!
//! Two bit-identity contracts are pinned here:
//!
//! * **Kernel**: the dispatched f32 microkernels (AVX2 FMA / NEON /
//!   scalar `mul_add`) produce bit-identical accumulators to the
//!   runtime-width scalar reference at every stride pattern the trainer
//!   uses (`Z = A·W`, `Gw = Aᵀ·dZ`, `dPrev = dZ·Wᵀ`), including partial
//!   tail panels and ReLU-sparse operands.
//! * **Trainer**: trained parameters and losses are bit-identical across
//!   pool lane counts and across kernel/panel-width choices — the
//!   property that lets the fleet shard retrains without changing a
//!   single result bit.
//!
//! Uses the in-repo harness (`rust/src/util/prop.rs`; the offline registry
//! has no proptest). Failing cases replay with `PROP_REPLAY=<seed>`.

use repro::coordinator::trainer::{
    he_init, native_train_step, native_train_step_fast, run_steps_native_pooled,
    NativeTrainState, TrainConfig, TrainScratch,
};
use repro::data::Dataset;
use repro::exec::{kernel, Kernel, WorkerPool, MAX_NR, MICRO_MR};
use repro::model::{Arch, Layer, Params};
use repro::prop_assert;
use repro::util::{prop, Rng};

fn tiny_arch() -> Arch {
    Arch {
        name: "tiny",
        layers: vec![Layer::fc(9, 16, true), Layer::fc(16, 3, false)],
        input_shape: vec![9],
        num_classes: 3,
        eval_batch: 16,
        train_batch: 16,
    }
}

/// Random activations with post-ReLU-style sparsity (exact zeros).
fn sparse_operand(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| if rng.bool(0.3) { 0.0 } else { rng.normal() }).collect()
}

fn random_dataset(rng: &mut Rng, arch: &Arch, n: usize) -> Dataset {
    let x: Vec<f32> = sparse_operand(rng, n * arch.input_len());
    let y: Vec<i32> = (0..n).map(|_| rng.below(arch.num_classes) as i32).collect();
    Dataset::new(x, y, arch.input_len(), arch.num_classes)
}

fn bits(p: &Params) -> Vec<u32> {
    p.layers.iter().flat_map(|(w, b)| w.iter().chain(b).map(|v| v.to_bits())).collect()
}

/// The dispatched f32 microkernels are bit-identical to the runtime-width
/// scalar reference for every (kh, stride, sparsity) case — covering all
/// three GEMM stride patterns the trainer issues, and the nr=4 fallback
/// against the reference at its own width. On AVX2/NEON hosts this pins
/// the real vector FMA kernels against scalar `f32::mul_add` chains.
#[test]
fn prop_f32_micro_kernels_match_scalar_reference() {
    prop::check("f32_micro_vs_reference", 0xF1, 60, |rng| {
        let kh = 1 + rng.below(40);
        // the trainer's stride patterns: rows contiguous (k_stride 1,
        // row_stride >= kh) and columns-of-A walks (k_stride = lead,
        // row_stride 1) — plus arbitrary combinations
        let (row_stride, k_stride) = match rng.below(3) {
            0 => (kh + rng.below(4), 1),
            1 => (1, kh + rng.below(4)),
            _ => (1 + rng.below(5), 1 + rng.below(5)),
        };
        let a_len = (MICRO_MR - 1) * row_stride + (kh - 1) * k_stride + 1;
        let a = sparse_operand(rng, a_len);
        for kr in [*kernel(), Kernel::scalar_fallback()] {
            let nr = kr.nr();
            let oracle = Kernel::scalar_reference(nr);
            let panel = sparse_operand(rng, kh * nr);

            let mut got = vec![f32::NAN; MICRO_MR * MAX_NR];
            let mut want = vec![f32::NAN; MICRO_MR * MAX_NR];
            kr.micro4_f32(&a, row_stride, k_stride, kh, &panel, &mut got);
            oracle.micro4_f32(&a, row_stride, k_stride, kh, &panel, &mut want);
            for i in 0..MICRO_MR * nr {
                prop_assert!(
                    got[i].to_bits() == want[i].to_bits(),
                    "micro4 {:?} nr={nr}: kh={kh} rs={row_stride} ks={k_stride} i={i}: \
                     {} != {}",
                    kr.isa(),
                    got[i],
                    want[i]
                );
            }

            let mut got1 = vec![f32::NAN; MAX_NR];
            let mut want1 = vec![f32::NAN; MAX_NR];
            kr.micro1_f32(&a, k_stride, kh, &panel, &mut got1);
            oracle.micro1_f32(&a, k_stride, kh, &panel, &mut want1);
            for j in 0..nr {
                prop_assert!(
                    got1[j].to_bits() == want1[j].to_bits(),
                    "micro1 {:?} nr={nr}: kh={kh} ks={k_stride} j={j}",
                    kr.isa()
                );
            }
        }
        Ok(())
    });
}

/// The packed-panel fast step computes the same gradients as the naive
/// triple-loop step to float tolerance (they are the same reduction in a
/// different association: fused mul_add chains vs separate mul+add, so
/// bit equality is not expected — closeness is).
#[test]
fn prop_fast_step_matches_naive_step_approximately() {
    prop::check("fast_vs_naive_step", 0xF2, 25, |rng| {
        let arch = tiny_arch();
        let b = arch.train_batch;
        let x = sparse_operand(rng, b * arch.input_len());
        let y: Vec<i32> = (0..b).map(|_| rng.below(arch.num_classes) as i32).collect();
        let seed = rng.below(1 << 20) as u64;
        let lr = 0.05;

        let mut naive = NativeTrainState::init(&arch, seed);
        let loss_naive = native_train_step(&arch, &mut naive, None, &x, &y, b, lr);

        let mut fast = NativeTrainState::init(&arch, seed);
        let mut scratch = TrainScratch::new(&arch, b);
        let loss_fast =
            native_train_step_fast(&arch, &mut fast, None, &x, &y, lr, &mut scratch, None);

        prop_assert!(
            (loss_naive - loss_fast).abs() <= 1e-4 * (1.0 + loss_naive.abs()),
            "loss diverged: naive {loss_naive} vs fast {loss_fast}"
        );
        for (li, ((wn, bn), (wf, bf))) in
            naive.params.layers.iter().zip(&fast.params.layers).enumerate()
        {
            for (i, (a, b)) in wn.iter().zip(wf).chain(bn.iter().zip(bf)).enumerate() {
                prop_assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
                    "layer {li} param {i}: naive {a} vs fast {b}"
                );
            }
        }
        Ok(())
    });
}

/// Training through the pooled driver is bit-identical at every lane
/// count — losses and trained parameters — including lane counts that
/// exceed the batch, and with the final-batch padding path exercised
/// (dataset size not a batch multiple).
#[test]
fn prop_pooled_training_is_bit_identical() {
    let pools: Vec<WorkerPool> = [1usize, 2, 3, 7].into_iter().map(WorkerPool::new).collect();
    prop::check("pooled_training_bits", 0xF3, 12, |rng| {
        let arch = tiny_arch();
        // 24..56 samples at batch 16: mostly not a batch multiple, so the
        // final-batch padding path runs
        let ds = random_dataset(rng, &arch, 24 + rng.below(33));
        let cfg = TrainConfig {
            steps: 5 + rng.below(4),
            lr: 0.05,
            end_lr_frac: 0.5,
            seed: rng.below(1 << 20) as u64,
            log_every: 0,
        };
        let mut single = NativeTrainState::init(&arch, cfg.seed);
        let losses = run_steps_native_pooled(&arch, &mut single, None, &ds, &cfg, None)
            .map_err(|e| e.to_string())?;
        for pool in &pools {
            let mut st = NativeTrainState::init(&arch, cfg.seed);
            let got = run_steps_native_pooled(&arch, &mut st, None, &ds, &cfg, Some(pool))
                .map_err(|e| e.to_string())?;
            prop_assert!(
                got.iter().map(|v| v.to_bits()).eq(losses.iter().map(|v| v.to_bits())),
                "losses differ at {} lanes (n={})",
                pool.lanes(),
                ds.len()
            );
            prop_assert!(
                bits(&st.params) == bits(&single.params),
                "params differ at {} lanes (n={})",
                pool.lanes(),
                ds.len()
            );
        }
        Ok(())
    });
}

/// The trained bits do not depend on which kernel computed them: the
/// dispatched ISA, the runtime-width scalar reference at the same panel
/// width, and the nr=4 scalar fallback all train identical parameters
/// (panel width only changes tail-panel zero padding, which never enters
/// an FMA chain's value).
#[test]
fn prop_kernel_and_panel_width_do_not_change_trained_bits() {
    prop::check("kernel_choice_bits", 0xF4, 15, |rng| {
        let arch = tiny_arch();
        let b = arch.train_batch;
        let x = sparse_operand(rng, b * arch.input_len());
        let y: Vec<i32> = (0..b).map(|_| rng.below(arch.num_classes) as i32).collect();
        let seed = rng.below(1 << 20) as u64;
        let steps = 2 + rng.below(4);

        let mut runs: Vec<Params> = Vec::new();
        for kr in [*kernel(), Kernel::scalar_reference(kernel().nr()), Kernel::scalar_fallback()]
        {
            let mut st = NativeTrainState::init(&arch, seed);
            let mut sc = TrainScratch::with_kernel(&arch, b, kr);
            for _ in 0..steps {
                native_train_step_fast(&arch, &mut st, None, &x, &y, 0.03, &mut sc, None);
            }
            runs.push(st.params);
        }
        prop_assert!(bits(&runs[0]) == bits(&runs[1]), "dispatched != scalar reference");
        prop_assert!(bits(&runs[0]) == bits(&runs[2]), "dispatched != nr=4 fallback");
        Ok(())
    });
}

/// Masked (FAP+T) training through the fast pooled path keeps pruned
/// weights exactly zero after every step, and the surviving weights
/// match the naive masked step to float tolerance.
#[test]
fn prop_masked_fast_training_keeps_pruned_weights_zero() {
    let pool = WorkerPool::new(3);
    prop::check("masked_fast_zeros", 0xF5, 15, |rng| {
        let arch = tiny_arch();
        let b = arch.train_batch;
        let masks: Vec<Vec<f32>> = arch
            .weighted_layers()
            .iter()
            .map(|l| (0..l.weight_len()).map(|_| if rng.bool(0.3) { 0.0 } else { 1.0 }).collect())
            .collect();
        let mut init = he_init(&arch, rng.below(1 << 20) as u64);
        init.apply_masks(&masks);

        let mut st = NativeTrainState::from_params(&arch, &init);
        let mut sc = TrainScratch::new(&arch, b);
        for step in 0..4 {
            let x = sparse_operand(rng, b * arch.input_len());
            let y: Vec<i32> = (0..b).map(|_| rng.below(arch.num_classes) as i32).collect();
            native_train_step_fast(
                &arch,
                &mut st,
                Some(&masks),
                &x,
                &y,
                0.05,
                &mut sc,
                Some(&pool),
            );
            for (li, ((w, _), m)) in st.params.layers.iter().zip(&masks).enumerate() {
                for (i, (&wv, &mv)) in w.iter().zip(m).enumerate() {
                    if mv == 0.0 {
                        prop_assert!(
                            wv == 0.0,
                            "pruned weight drifted: layer {li} idx {i} = {wv} (step {step})"
                        );
                    }
                }
            }
        }
        // the mask left something alive, and training moved it
        let moved = st
            .params
            .layers
            .iter()
            .zip(&init.layers)
            .any(|((w, _), (w0, _))| w.iter().zip(w0).any(|(a, b)| a != b));
        prop_assert!(moved, "masked training moved no weights");
        Ok(())
    });
}
