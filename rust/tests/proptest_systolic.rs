//! Property tests over the systolic substrate (in-repo harness — the
//! offline registry has no proptest; see rust/src/util/prop.rs).

use repro::faults::{FaultMap, StuckAt};
use repro::prop_assert;
use repro::systolic::{SystolicArray, TiledMatmul};
use repro::util::{prop, Rng};

fn random_fault_map(rng: &mut Rng, n: usize, max_faults: usize) -> FaultMap {
    let mut fm = FaultMap::healthy(n);
    for _ in 0..rng.below(max_faults + 1) {
        fm.add(StuckAt {
            row: rng.below(n) as u16,
            col: rng.below(n) as u16,
            bit: rng.below(32) as u8,
            value: rng.bool(0.5),
        });
    }
    fm
}

/// Healthy array == exact integer matmul (wrapping).
#[test]
fn prop_healthy_array_is_matmul() {
    prop::check("healthy_array_is_matmul", 0xA1, 40, |rng| {
        let n = 1 + rng.below(10);
        let k = 1 + rng.below(n);
        let cols = 1 + rng.below(n);
        let batch = 1 + rng.below(5);
        let mut arr = SystolicArray::healthy(n);
        let w: Vec<i32> = (0..k * cols).map(|_| rng.below(255) as i32 - 127).collect();
        arr.load_weights(&w, k, cols);
        let a: Vec<i32> = (0..batch * k).map(|_| rng.below(255) as i32 - 127).collect();
        let got = arr.matmul(&a, batch, k, cols);
        for b in 0..batch {
            for c in 0..cols {
                let want: i32 = (0..k)
                    .map(|r| a[b * k + r].wrapping_mul(w[r * cols + c]))
                    .fold(0i32, |acc, v| acc.wrapping_add(v));
                prop_assert!(
                    got[b * cols + c] == want,
                    "({b},{c}): {} != {want}",
                    got[b * cols + c]
                );
            }
        }
        Ok(())
    });
}

/// Cycle-accurate mode computes the same values as the functional mode,
/// for any fault pattern, and drains in (K-1)+(C-1)+B cycles.
#[test]
fn prop_cycle_accurate_equals_functional() {
    prop::check("cycle_accurate_equals_functional", 0xA2, 30, |rng| {
        let n = 2 + rng.below(8);
        let k = 1 + rng.below(n);
        let cols = 1 + rng.below(n);
        let batch = 1 + rng.below(6);
        let fm = random_fault_map(rng, n, 6);
        let mut arr = SystolicArray::with_faults(&fm);
        if rng.bool(0.5) {
            arr.bypass_faulty();
        }
        let w: Vec<i32> = (0..k * cols).map(|_| rng.below(255) as i32 - 127).collect();
        arr.load_weights(&w, k, cols);
        let a: Vec<i32> = (0..batch * k).map(|_| rng.below(255) as i32 - 127).collect();
        let f = arr.matmul(&a, batch, k, cols);
        let (c, cycles) = arr.matmul_cycle_accurate(&a, batch, k, cols);
        prop_assert!(f == c, "values diverge (n={n} k={k} cols={cols} b={batch})");
        let expect = (k - 1 + cols - 1 + batch) as u64;
        prop_assert!(cycles == expect, "cycles {cycles} != {expect}");
        Ok(())
    });
}

/// FAP invariant (paper §5.1): bypassing every faulty MAC makes the faulty
/// array compute exactly the pruned-weight matmul on a healthy array.
#[test]
fn prop_fap_bypass_equals_pruned_weights() {
    prop::check("fap_bypass_equals_pruned", 0xA3, 30, |rng| {
        let n = 2 + rng.below(6);
        let k = 1 + rng.below(3 * n);
        let m = 1 + rng.below(3 * n);
        let batch = 1 + rng.below(4);
        let fm = random_fault_map(rng, n, 8);
        let a: Vec<i32> = (0..batch * k).map(|_| rng.below(255) as i32 - 127).collect();
        let w: Vec<i32> = (0..k * m).map(|_| rng.below(255) as i32 - 127).collect();

        let mut fap = TiledMatmul::new(&fm, true);
        let got = fap.matmul(&a, &w, batch, k, m);

        let mut wp = w.clone();
        for r in 0..k {
            for c in 0..m {
                if fm.is_faulty(r % n, c % n) {
                    wp[r * m + c] = 0;
                }
            }
        }
        let mut healthy = TiledMatmul::new(&FaultMap::healthy(n), false);
        let want = healthy.matmul(&a, &wp, batch, k, m);
        prop_assert!(got == want, "FAP != pruned (n={n} k={k} m={m})");
        Ok(())
    });
}

/// The paper's counter-claim: loading zero weights into faulty MACs (no
/// bypass) is NOT equivalent to pruning whenever a stuck bit actually
/// flips an accumulator bit on some input.
#[test]
fn prop_zero_weight_differs_from_bypass_for_stuck_at_1() {
    prop::check("zero_weight_not_bypass", 0xA4, 25, |rng| {
        let n = 2 + rng.below(6);
        let r = rng.below(n);
        let c = rng.below(n);
        // stuck-at-1 on a high bit is always observable on a zero sum
        let fm = FaultMap::from_faults(
            n,
            [StuckAt { row: r as u16, col: c as u16, bit: 28 + rng.below(3) as u8, value: true }],
        );
        let k = n; // single pass
        let batch = 1 + rng.below(3);
        // non-negative operands keep partial sums small and positive, so a
        // high stuck-at-1 bit is guaranteed observable (with signed inputs
        // a negative passing sum can already have the bit set — the fault
        // is then silent on that input, which is fine for hardware but
        // would make this property flaky)
        let mut w = vec![0i32; k * n];
        for i in 0..k {
            for j in 0..n {
                w[i * n + j] = rng.below(128) as i32;
            }
        }
        w[r * n + c] = 0; // "prune" by zero weight
        let a: Vec<i32> = (0..batch * k).map(|_| rng.below(128) as i32).collect();

        let mut no_byp = TiledMatmul::new(&fm, false);
        let zero_weight = no_byp.matmul(&a, &w, batch, k, n);
        let mut healthy = TiledMatmul::new(&FaultMap::healthy(n), false);
        let pruned = healthy.matmul(&a, &w, batch, k, n);
        prop_assert!(
            zero_weight != pruned,
            "stuck-at-1 bit {} at ({r},{c}) was silent with zero weight",
            fm.faults()[0].bit
        );
        Ok(())
    });
}

/// Tiling invariance for healthy arrays: any array size computes the same
/// logical matmul.
#[test]
fn prop_tiling_invariant_for_healthy_arrays() {
    prop::check("tiling_invariance", 0xA5, 25, |rng| {
        let k = 1 + rng.below(30);
        let m = 1 + rng.below(30);
        let batch = 1 + rng.below(4);
        let a: Vec<i32> = (0..batch * k).map(|_| rng.below(255) as i32 - 127).collect();
        let w: Vec<i32> = (0..k * m).map(|_| rng.below(255) as i32 - 127).collect();
        let n1 = 1 + rng.below(8);
        let n2 = 1 + rng.below(16);
        let r1 = TiledMatmul::new(&FaultMap::healthy(n1), false).matmul(&a, &w, batch, k, m);
        let r2 = TiledMatmul::new(&FaultMap::healthy(n2), false).matmul(&a, &w, batch, k, m);
        prop_assert!(r1 == r2, "n={n1} vs n={n2} differ on healthy arrays");
        Ok(())
    });
}
