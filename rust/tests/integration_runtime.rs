//! Runtime integration: artifacts load, compile and agree with the python
//! golden vectors (cross-language, cross-XLA-version checks).
//!
//! Requires `make artifacts` (the Makefile `test` target guarantees it).

use repro::model::arch;
use repro::runtime::{lit_f32, lit_i32, scalar_i32, Runtime};
use repro::systolic::fixed;

fn artifacts_dir() -> String {
    std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn read_lines(path: &str) -> Vec<String> {
    let p = format!("{}/{}", artifacts_dir(), path);
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("{p}: {e} — run `make artifacts`"))
        .lines()
        .map(|s| s.to_string())
        .collect()
}

fn parse_f32s(line: &str) -> Vec<f32> {
    line.split_whitespace().map(|v| v.parse().unwrap()).collect()
}

fn parse_i32s(line: &str) -> Vec<i32> {
    line.split_whitespace().map(|v| v.parse().unwrap()).collect()
}

#[test]
fn manifest_covers_all_hlo_files() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let m = rt.manifest();
    assert!(m.artifacts.len() >= 10, "expected a full artifact set");
    for spec in m.artifacts.values() {
        assert!(
            m.hlo_path(spec).exists(),
            "manifest references missing file {}",
            spec.file
        );
        assert!(!spec.outputs.is_empty(), "{} has no outputs", spec.name);
    }
}

#[test]
fn quantization_matches_python_bit_for_bit() {
    let lines = read_lines("testvectors/quant.txt");
    let hdr: Vec<&str> = lines[0].split_whitespace().collect();
    let scale: f32 = hdr[1].parse().unwrap();
    let xs = parse_f32s(&lines[1]);
    let want = parse_i32s(&lines[2]);
    let got = fixed::quantize_vec(&xs, scale);
    assert_eq!(got, want, "rust quantize diverged from python");
}

#[test]
fn faulty_matmul_artifact_matches_python_golden() {
    let lines = read_lines("testvectors/faulty_matmul.txt");
    let hdr: Vec<usize> = lines[0].split_whitespace().map(|v| v.parse().unwrap()).collect();
    let (b, k, n) = (hdr[0], hdr[1], hdr[2]);
    let arrs: Vec<Vec<i32>> = lines[1..7].iter().map(|l| parse_i32s(l)).collect();

    let rt = Runtime::new(artifacts_dir()).unwrap();
    let exe = rt.load("faulty_matmul_test").unwrap();
    let inputs = vec![
        lit_i32(&arrs[0], &[b, k]).unwrap(),
        lit_i32(&arrs[1], &[k, n]).unwrap(),
        lit_i32(&arrs[2], &[k, n]).unwrap(),
        lit_i32(&arrs[3], &[k, n]).unwrap(),
        lit_i32(&arrs[4], &[k, n]).unwrap(),
    ];
    let outs = exe.run(&inputs).unwrap();
    let got = exe.i32_out(&outs, 0).unwrap();
    assert_eq!(got, arrs[5], "HLO faulty matmul != python golden");
}

#[test]
fn mnist_fwd_artifact_matches_python_logits() {
    let lines = read_lines("testvectors/mnist_fwd.txt");
    let hdr: Vec<usize> = lines[0].split_whitespace().map(|v| v.parse().unwrap()).collect();
    let (seed, batch, din, classes) = (hdr[0], hdr[1], hdr[2], hdr[3]);
    let x = parse_f32s(&lines[1]);
    let want = parse_f32s(&lines[2]);

    let rt = Runtime::new(artifacts_dir()).unwrap();
    let init = rt.load("mnist_init").unwrap();
    let params = init.run(&[scalar_i32(seed as i32)]).unwrap();
    let fwd = rt.load("mnist_fwd").unwrap();
    let mut inputs = params;
    inputs.push(lit_f32(&x, &[batch, din]).unwrap());
    let outs = fwd.run(&inputs).unwrap();
    let got = fwd.f32_out(&outs, 0).unwrap();

    assert_eq!(got.len(), batch * classes);
    let mut max_err = 0.0f32;
    for (g, w) in got.iter().zip(&want) {
        max_err = max_err.max((g - w).abs());
    }
    // float path across two XLA versions: tolerance, not bit-equality
    assert!(max_err < 1e-3, "mnist fwd max err {max_err}");
}

#[test]
fn archs_txt_matches_rust_mirror() {
    let lines = read_lines("archs.txt");
    for name in ["mnist", "timit", "alexnet32"] {
        let a = arch::by_name(name).unwrap();
        let hdr = lines
            .iter()
            .find(|l| l.starts_with(&format!("arch {name} ")))
            .unwrap_or_else(|| panic!("{name} missing from archs.txt"));
        let field = |key: &str| -> String {
            hdr.split_whitespace()
                .find_map(|t| t.strip_prefix(&format!("{key}=")))
                .unwrap_or_else(|| panic!("{name}: no {key}"))
                .to_string()
        };
        assert_eq!(field("classes"), a.num_classes.to_string(), "{name} classes");
        assert_eq!(field("params"), a.param_count().to_string(), "{name} params");
        assert_eq!(field("eval_batch"), a.eval_batch.to_string(), "{name} eval batch");
        assert_eq!(field("train_batch"), a.train_batch.to_string(), "{name} train batch");
    }
}

#[test]
fn mnist_pallas_and_scan_faulty_artifacts_agree() {
    // The L1 Pallas kernel lowered into a full model HLO must agree with
    // the scan implementation bit-for-bit on the same inputs.
    use repro::coordinator::evaluate::Evaluator;
    use repro::data;
    use repro::faults::{inject_uniform, FaultSpec};
    use repro::mapping::{LayerMasks, MaskKind};
    use repro::model::quant::calibrate_mlp;
    use repro::model::Params;
    use repro::util::Rng;

    let rt = Runtime::new(artifacts_dir()).unwrap();
    if !rt.has("mnist_faulty_fwd_pallas") {
        eprintln!("skipping: pallas artifact not built (--fast artifacts)");
        return;
    }
    let a = arch::by_name("mnist").unwrap();
    let init = rt.load("mnist_init").unwrap();
    let plits = init.run(&[scalar_i32(3)]).unwrap();
    let flat: Vec<Vec<f32>> = plits.iter().map(|l| l.to_vec::<f32>().unwrap()).collect();
    let params = Params::from_flat(&a, flat).unwrap();

    let (_, test) = data::for_arch("mnist", 64, 256, 9).unwrap();
    let calib = calibrate_mlp(&a, &params, &test.x[..64 * 784], 64);
    let fm = inject_uniform(FaultSpec::new(256), 12, &mut Rng::new(4));
    let masks = LayerMasks::build(&a, &fm, MaskKind::Unmitigated);
    let ev = Evaluator::new(&rt);
    let acc_scan = ev.accuracy_faulty(&a, &params, &masks, &calib, &test, false).unwrap();
    let acc_pallas = ev.accuracy_faulty(&a, &params, &masks, &calib, &test, true).unwrap();
    assert_eq!(acc_scan, acc_pallas, "pallas vs scan artifact accuracy differs");
}
