//! Observability end-to-end: the determinism contract (same seed + same
//! config → byte-identical JSONL event log, Perfetto trace, and metrics
//! snapshot, with the trace invariant across phase-2 worker counts), the
//! DES-only serving mode (skipped exec phase reports `accuracy: null`,
//! never 0.0, under a stable JSON schema), the conservation invariant on
//! the admission counters, zero recording when disabled, and the shared
//! nearest-rank quantile semantics between the fleet scheduler and
//! `obs::hist`.

use std::collections::BTreeSet;

use repro::chip::{Backend, Chip, Engine};
use repro::coordinator::trainer::{train_baseline_native, TrainConfig};
use repro::data::Dataset;
use repro::fleet::{
    fleet_json, percentile, provision_fleet, run_lifetime, run_lifetime_traced, serve_open,
    serve_open_traced, ArrivalProcess, BatcherConfig, ChipUnit, FleetConfig, OpenWorkloadConfig,
    RoutingPolicy, YieldDist,
};
use repro::mapping::MaskKind;
use repro::model::quant::{calibrate_mlp, Calibration};
use repro::model::{Arch, Layer, Params};
use repro::obs::{self, Trace};
use repro::util::Rng;

fn tiny_arch() -> Arch {
    Arch {
        name: "tiny",
        layers: vec![Layer::fc(12, 16, true), Layer::fc(16, 4, false)],
        input_shape: vec![12],
        num_classes: 4,
        eval_batch: 16,
        train_batch: 16,
    }
}

fn clustered(n: usize, seed: u64) -> Dataset {
    let mut crng = Rng::new(77);
    let centers: Vec<Vec<f32>> =
        (0..4).map(|_| (0..12).map(|_| crng.normal() * 2.0).collect()).collect();
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * 12);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % 4;
        y.push(c as i32);
        for d in 0..12 {
            x.push(centers[c][d] + rng.normal() * 0.5);
        }
    }
    Dataset::new(x, y, 12, 4)
}

fn bundle() -> (Arch, Params, Calibration, Dataset, Dataset) {
    let arch = tiny_arch();
    let train = clustered(320, 1);
    let test = clustered(160, 2);
    let cfg = TrainConfig { steps: 300, seed: 5, ..Default::default() };
    let (golden, _) = train_baseline_native(&arch, &train, &cfg).unwrap();
    let calib = calibrate_mlp(&arch, &golden, &train.x[..64 * 12], 64);
    (arch, golden, calib, train, test)
}

fn open_chips(arch: &Arch, n: usize) -> Vec<Chip> {
    (0..n)
        .map(|i| {
            Chip::new(arch.clone())
                .array_n(8)
                .inject(3 + i, 200 + i as u64)
                .detect()
                .unwrap()
                .mitigate(MaskKind::FapBypass)
                .threads(1)
        })
        .collect()
}

fn open_cfg(rate_rps: f64, offered: usize, execute: bool) -> OpenWorkloadConfig {
    OpenWorkloadConfig {
        backend: Backend::Plan,
        policy: RoutingPolicy::RoundRobin,
        arrival: ArrivalProcess::Poisson,
        rate_rps,
        offered,
        batcher: BatcherConfig {
            batch_max: 8,
            max_batch_age_us: 100.0,
            queue_timeout_us: 5_000.0,
            queue_depth: 1,
        },
        workers: 2,
        execute,
        seed: 13,
    }
}

fn fleet_cfg(execute: bool) -> FleetConfig {
    FleetConfig {
        chips: 2,
        array_n: 8,
        seed: 17,
        policy: RoutingPolicy::RoundRobin,
        hours: 8_000.0,
        life_steps: 2,
        yield_dist: YieldDist::Fixed(1),
        eol_fault_rate: 0.2,
        aging_beta: 2.0,
        slo_frac: 0.5,
        batch: 8,
        queue_depth: 2,
        batches_per_chip: 2,
        workers: 2,
        retrain_epochs: 1,
        retrain_downtime_hours: 50.0,
        max_retrains: 1,
        managed: true,
        escape_prob: 0.0,
        execute,
        ..FleetConfig::default()
    }
}

/// Every `"key":` occurrence in a rendered JSON document — the schema
/// fingerprint the stability tests compare. String *values* are never
/// followed by `:`, so the scan collects exactly the object keys.
fn json_keys(render: &str) -> BTreeSet<String> {
    let b = render.as_bytes();
    let mut keys = BTreeSet::new();
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'"' {
            let start = i + 1;
            let mut j = start;
            while j < b.len() && b[j] != b'"' {
                if b[j] == b'\\' {
                    j += 1;
                }
                j += 1;
            }
            let mut k = j + 1;
            while k < b.len() && b[k].is_ascii_whitespace() {
                k += 1;
            }
            if k < b.len() && b[k] == b':' {
                keys.insert(String::from_utf8_lossy(&b[start..j]).into_owned());
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    keys
}

/// Satellite: one quantile implementation in the repo. The scheduler's
/// `percentile` must be bit-identical to `obs::hist::nearest_rank` on
/// arbitrary sorted samples — including the empty, singleton, and
/// duplicate-heavy cases.
#[test]
fn scheduler_percentile_is_bit_identical_to_obs_hist() {
    let mut rng = Rng::new(0xB17);
    for _ in 0..200 {
        let len = rng.below(50);
        let mut v: Vec<f64> = (0..len).map(|_| (rng.normal() as f64 * 1e3).round()).collect();
        v.sort_by(|a, b| a.total_cmp(b));
        for p in [0.0, 0.5, 0.99, 0.999, 1.0, rng.f64()] {
            let (a, b) = (percentile(&v, p), obs::nearest_rank(&v, p));
            assert_eq!(a.to_bits(), b.to_bits(), "p={p} len={len}: {a} vs {b}");
        }
    }
}

/// The tentpole's determinism contract on the open loop: same seed + same
/// config produces byte-identical JSONL and Perfetto renders, and the
/// trace — a phase-1 DES artifact — is further identical across phase-2
/// worker counts. The admission counters obey conservation:
/// served + shed + timed_out == offered.
#[test]
fn open_loop_trace_is_byte_identical_across_runs_and_workers() {
    let (arch, golden, calib, _train, test) = bundle();
    // 3 chips so the workers=3 run passes the workers <= chips validation
    let chips = open_chips(&arch, 3);
    let _g = obs::test_guard();
    let run = |workers: usize| {
        obs::reset_metrics();
        let units: Vec<ChipUnit<'_>> = chips
            .iter()
            .enumerate()
            .map(|(i, c)| ChipUnit { id: i, chip: c, params: &golden, weight: 1.0 })
            .collect();
        let mut cfg = open_cfg(1e9, 250, true);
        cfg.workers = workers;
        let mut trace = Trace::new();
        let rep = serve_open_traced(&units, &calib, &test, &cfg, Some(&mut trace)).unwrap();
        assert!(rep.executed);
        assert!(rep.open.as_ref().unwrap().conservation_ok());
        (trace.render_jsonl(), trace.render_chrome(), obs::snapshot_json().render())
    };
    let (j1, c1, m1) = run(1);
    let (j2, c2, m2) = run(1);
    let (j3, c3, _m3) = run(3);

    assert!(!j1.is_empty(), "traced serving must emit events");
    assert!(c1.contains("traceEvents"), "chrome render must be a trace-event document");
    assert!(c1.contains("chip 0"), "chip tracks must be named");
    assert_eq!(j1, j2, "JSONL must be byte-identical across same-seed runs");
    assert_eq!(c1, c2, "Perfetto trace must be byte-identical across same-seed runs");
    assert_eq!(m1, m2, "metrics snapshot must be byte-identical across same-seed runs");
    assert_eq!(j1, j3, "JSONL must not depend on phase-2 worker count");
    assert_eq!(c1, c3, "Perfetto trace must not depend on phase-2 worker count");

    // conservation on the live counters of the last run
    let r = obs::registry();
    let offered = r.counter("fleet.requests.offered").value();
    let served = r.counter("fleet.requests.served").value();
    let shed = r.counter("fleet.requests.shed").value();
    let timed_out = r.counter("fleet.requests.timed_out").value();
    assert_eq!(offered, 250);
    assert_eq!(served + shed + timed_out, offered, "admission counters must conserve");
}

/// Same contract over a whole managed lifetime: health-loop instants and
/// per-step serving windows land on the virtual clock only, so two
/// provision+lifetime runs render byte-identical traces and metrics, and
/// the execution worker count never leaks into the trace.
#[test]
fn fleet_lifetime_trace_and_metrics_are_deterministic() {
    let (arch, golden, calib, train, test) = bundle();
    let _g = obs::test_guard();
    let run = |workers: usize| {
        obs::reset_metrics();
        let mut engine = Engine::new(Backend::Plan, None).unwrap();
        let cfg = FleetConfig { workers, ..fleet_cfg(true) };
        let mut fleet =
            provision_fleet(&mut engine, cfg, &arch, &golden, &calib, &train, &test).unwrap();
        let mut trace = Trace::new();
        let out =
            run_lifetime_traced(&mut engine, &mut fleet, &golden, &train, &test, Some(&mut trace))
                .unwrap();
        assert!(out.total_samples > 0);
        (trace.render_jsonl(), trace.render_chrome(), obs::snapshot_json().render())
    };
    let (j1, c1, m1) = run(2);
    let (j2, c2, m2) = run(2);
    let (j3, c3, _m3) = run(1);
    assert!(!j1.is_empty());
    assert!(c1.contains("health loop"), "health-loop track must be named");
    assert_eq!(j1, j2, "lifetime JSONL must be byte-identical across runs");
    assert_eq!(c1, c2, "lifetime Perfetto trace must be byte-identical across runs");
    assert_eq!(m1, m2, "lifetime metrics snapshot must be byte-identical across runs");
    assert_eq!(j1, j3, "lifetime JSONL must not depend on worker count");
    assert_eq!(c1, c3, "lifetime Perfetto trace must not depend on worker count");
}

/// DES-only serving (`execute: false`) keeps every phase-1 statistic
/// bit-identical to the executing run and reports the unmeasured exec
/// phase honestly: zero samples, `executed == false` — never a fake 0.0
/// accuracy.
#[test]
fn des_only_serving_matches_phase1_and_skips_exec_stats() {
    let (arch, golden, calib, _train, test) = bundle();
    let chips = open_chips(&arch, 2);
    let _g = obs::test_lock(false);
    let run = |execute: bool| {
        let units: Vec<ChipUnit<'_>> = chips
            .iter()
            .enumerate()
            .map(|(i, c)| ChipUnit { id: i, chip: c, params: &golden, weight: 1.0 })
            .collect();
        serve_open(&units, &calib, &test, &open_cfg(1e9, 200, execute)).unwrap()
    };
    let (et, ef) = (run(true), run(false));
    let (ot, of) = (et.open.as_ref().unwrap(), ef.open.as_ref().unwrap());
    // phase 1 is identical whether or not phase 2 runs
    assert_eq!(ot.outcomes, of.outcomes);
    assert_eq!(ot.latencies_us, of.latencies_us);
    assert_eq!(ot.offered, of.offered);
    assert_eq!(ot.served, of.served);
    assert_eq!(ot.shed, of.shed);
    assert_eq!(ot.timed_out, of.timed_out);
    assert_eq!(ot.batches, of.batches);
    assert_eq!(ot.virtual_secs, of.virtual_secs);
    assert_eq!(et.sim_cycles, ef.sim_cycles, "virtual cycle accounting is a phase-1 quantity");
    // phase 2 honestly skipped: nothing measured, nothing faked
    assert!(et.executed && et.samples > 0 && et.correct > 0);
    assert!(!ef.executed, "skipped exec phase must be flagged");
    assert_eq!(ef.samples, 0);
    assert_eq!(ef.correct, 0);
}

/// `fleet.json` schema stability across execute modes: the key set is
/// identical whether phase 2 ran or not, and the skipped mode renders
/// `accuracy`/`fleet_accuracy` as null with `exec_phase: "skipped"`.
#[test]
fn fleet_json_schema_is_stable_across_execute_modes() {
    let (arch, golden, calib, train, test) = bundle();
    let _g = obs::test_lock(false);
    let render = |execute: bool| {
        let mut engine = Engine::new(Backend::Plan, None).unwrap();
        let mut fleet =
            provision_fleet(&mut engine, fleet_cfg(execute), &arch, &golden, &calib, &train, &test)
                .unwrap();
        let out = run_lifetime(&mut engine, &mut fleet, &golden, &train, &test).unwrap();
        fleet_json(&fleet, &out, "plan").render()
    };
    let (jt, jf) = (render(true), render(false));
    assert!(jt.contains("\"exec_phase\": \"executed\""), "{jt}");
    assert!(!jt.contains("\"fleet_accuracy\": null"));
    assert!(jf.contains("\"exec_phase\": \"skipped\""), "{jf}");
    assert!(jf.contains("\"fleet_accuracy\": null"), "skipped exec must report null accuracy");
    assert!(jf.contains("\"accuracy\": null"), "per-step accuracy must be null when skipped");
    assert_eq!(
        json_keys(&jt),
        json_keys(&jf),
        "fleet.json key set must not depend on the execute mode"
    );
}

/// Disabled observability records nothing: with the flag off, a full
/// serving run leaves every counter at zero — the instrumented hot paths
/// pay one relaxed load and bail.
#[test]
fn disabled_observability_records_nothing() {
    let (arch, golden, calib, _train, test) = bundle();
    let chips = open_chips(&arch, 1);
    let _g = obs::test_lock(false);
    obs::reset_metrics();
    let units: Vec<ChipUnit<'_>> = chips
        .iter()
        .enumerate()
        .map(|(i, c)| ChipUnit { id: i, chip: c, params: &golden, weight: 1.0 })
        .collect();
    let rep = serve_open(&units, &calib, &test, &open_cfg(0.0, 60, true)).unwrap();
    assert!(rep.samples > 0, "serving itself must be unaffected");
    let r = obs::registry();
    for name in [
        "fleet.requests.offered",
        "fleet.requests.served",
        "fleet.batches.dispatched",
        "exec.kernel.dispatch",
        "chip.quantize.values",
    ] {
        assert_eq!(r.counter(name).value(), 0, "{name} recorded while disabled");
    }
}
