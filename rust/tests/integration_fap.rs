//! End-to-end FAP pipeline on a real trained model (mnist):
//! train -> inject -> prune -> evaluate, checking the paper's ordering:
//! unmitigated faulty accuracy << FAP accuracy ≈ baseline accuracy.

use repro::coordinator::evaluate::Evaluator;
use repro::coordinator::fap::apply_fap;
use repro::coordinator::trainer::{train_baseline, TrainConfig};
use repro::data;
use repro::faults::{inject_uniform, FaultSpec};
use repro::mapping::{LayerMasks, MaskKind};
use repro::model::arch;
use repro::model::quant::calibrate_mlp;
use repro::runtime::Runtime;
use repro::util::Rng;

fn artifacts_dir() -> String {
    std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

#[test]
fn fap_pipeline_end_to_end() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let a = arch::by_name("mnist").unwrap();
    let (train, test) = data::for_arch("mnist", 1500, 500, 11).unwrap();
    let cfg = TrainConfig { steps: 140, lr: 0.05, seed: 11, log_every: 0, ..Default::default() };
    let (baseline, losses) = train_baseline(&rt, &a, &train, &cfg).unwrap();
    assert!(
        losses.last().unwrap() < &0.5,
        "baseline failed to learn: final loss {}",
        losses.last().unwrap()
    );

    let ev = Evaluator::new(&rt);
    let base_acc = ev.accuracy(&a, &baseline, &test).unwrap();
    assert!(base_acc > 0.9, "baseline accuracy {base_acc}");

    // moderate fault rate: 10% of a 64x64 grid
    let n = 64;
    let fm = inject_uniform(FaultSpec::new(n), 410, &mut Rng::new(5));
    let calib = calibrate_mlp(&a, &baseline, &train.x[..64 * 784], 64);

    // (1) unmitigated: accuracy collapses
    let unmit = LayerMasks::build(&a, &fm, MaskKind::Unmitigated);
    let faulty_acc = ev
        .accuracy_faulty(&a, &baseline, &unmit, &calib, &test, false)
        .unwrap();

    // (2) FAP: prune + healthy float path
    let (fap_params, masks, report) = apply_fap(&a, &baseline, &fm);
    let fap_acc = ev.accuracy(&a, &fap_params, &test).unwrap();

    // (3) FAP running on the faulty chip itself (bypass masks live)
    let fap_on_chip = ev
        .accuracy_faulty(&a, &fap_params, &masks, &calib, &test, false)
        .unwrap();

    eprintln!(
        "baseline {base_acc:.3} | unmitigated {faulty_acc:.3} | FAP {fap_acc:.3} | FAP-on-chip {fap_on_chip:.3}"
    );
    assert!(
        faulty_acc < base_acc - 0.15,
        "unmitigated faults should hurt: {faulty_acc} vs {base_acc}"
    );
    assert!(fap_acc > faulty_acc + 0.1, "FAP should recover accuracy");
    assert!(fap_acc > base_acc - 0.1, "FAP should stay near baseline at 10% faults");
    // bypassing on the faulty chip must track the pruned float model
    // closely (quantization noise only)
    assert!(
        (fap_on_chip - fap_acc).abs() < 0.05,
        "FAP-on-chip {fap_on_chip} vs pruned-float {fap_acc}"
    );
    assert!(report.pruned_weights > 0);
    assert!((report.fault_rate - 0.1).abs() < 0.01);
}

#[test]
fn fap_with_zero_faults_is_identity() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let a = arch::by_name("mnist").unwrap();
    let (train, test) = data::for_arch("mnist", 800, 256, 12).unwrap();
    let cfg = TrainConfig { steps: 80, lr: 0.05, seed: 12, log_every: 0, ..Default::default() };
    let (baseline, _) = train_baseline(&rt, &a, &train, &cfg).unwrap();
    let (fap_params, _, report) = apply_fap(&a, &baseline, &repro::faults::FaultMap::healthy(64));
    assert_eq!(report.pruned_weights, 0);
    let ev = Evaluator::new(&rt);
    let b = ev.accuracy(&a, &baseline, &test).unwrap();
    let f = ev.accuracy(&a, &fap_params, &test).unwrap();
    assert_eq!(b, f, "healthy FAP must not change the model");
}
