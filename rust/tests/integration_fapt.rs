//! FAP+T (Algorithm 1) integration: retraining recovers accuracy lost to
//! aggressive pruning, pruned weights stay exactly zero, and the full
//! provisioning flow (detect -> FAP -> FAP+T) holds together.

use repro::coordinator::evaluate::Evaluator;
use repro::coordinator::fap::apply_fap;
use repro::coordinator::fapt::{fapt_retrain, provision_chip, FaptConfig};
use repro::coordinator::trainer::{train_baseline, TrainConfig};
use repro::data;
use repro::faults::{inject_uniform, FaultSpec};
use repro::model::arch;
use repro::runtime::Runtime;
use repro::util::Rng;

fn artifacts_dir() -> String {
    std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

#[test]
fn fapt_recovers_accuracy_at_high_fault_rate() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let a = arch::by_name("mnist").unwrap();
    let (train, test) = data::for_arch("mnist", 1500, 500, 21).unwrap();
    let cfg = TrainConfig { steps: 140, lr: 0.05, seed: 21, log_every: 0, ..Default::default() };
    let (baseline, _) = train_baseline(&rt, &a, &train, &cfg).unwrap();
    let ev = Evaluator::new(&rt);
    let base_acc = ev.accuracy(&a, &baseline, &test).unwrap();

    // 50% fault rate — the paper's extreme point where FAP alone degrades
    let n = 32;
    let fm = inject_uniform(FaultSpec::new(n), n * n / 2, &mut Rng::new(6));
    let (fap_params, masks, _) = apply_fap(&a, &baseline, &fm);
    let fap_acc = ev.accuracy(&a, &fap_params, &test).unwrap();

    let fcfg = FaptConfig { max_epochs: 3, lr: 0.01, seed: 21, snapshot_epochs: vec![1] };
    let res = fapt_retrain(&rt, &a, &fap_params, &masks.prune, &train, &fcfg).unwrap();
    let fapt_acc = ev.accuracy(&a, &res.params, &test).unwrap();

    eprintln!("base {base_acc:.3} | FAP@50% {fap_acc:.3} | FAP+T {fapt_acc:.3}");
    assert!(fap_acc < base_acc - 0.02, "50% pruning should cost accuracy");
    assert!(
        fapt_acc > fap_acc + 0.02,
        "retraining should recover: FAP {fap_acc} -> FAP+T {fapt_acc}"
    );
    assert!(res.epoch_losses.len() == 3);
    assert!(
        res.epoch_losses[2] < res.epoch_losses[0],
        "retraining loss should fall: {:?}",
        res.epoch_losses
    );
    assert_eq!(res.snapshots.len(), 1);

    // Algorithm 1 line 7: pruned weights stay *exactly* zero
    for ((w, _), m) in res.params.layers.iter().zip(&masks.prune) {
        for (wi, &mi) in w.iter().zip(m) {
            if mi == 0.0 {
                assert_eq!(*wi, 0.0, "pruned weight drifted during retraining");
            }
        }
    }
}

#[test]
fn provision_chip_full_flow() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let a = arch::by_name("mnist").unwrap();
    let (train, test) = data::for_arch("mnist", 1200, 400, 31).unwrap();
    let cfg = TrainConfig { steps: 120, lr: 0.05, seed: 31, log_every: 0, ..Default::default() };
    let (baseline, _) = train_baseline(&rt, &a, &train, &cfg).unwrap();

    let n = 32;
    let fm = inject_uniform(FaultSpec::new(n), 100, &mut Rng::new(7));
    let fcfg = FaptConfig { max_epochs: 2, lr: 0.01, seed: 31, snapshot_epochs: vec![] };
    let out = provision_chip(&rt, &a, &baseline, &fm, &train, &fcfg).unwrap();

    // post-fab localization found every injected fault, no false positives
    assert_eq!(out.detected, fm.faulty_mac_count());
    assert_eq!(out.fault_map.faulty_macs(), fm.faulty_macs());

    let ev = Evaluator::new(&rt);
    let acc = ev.accuracy(&a, &out.result.params, &test).unwrap();
    assert!(acc > 0.85, "provisioned chip accuracy {acc}");
    assert!(out.result.secs_per_epoch > 0.0);
}
