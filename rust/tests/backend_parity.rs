//! Backend equivalence: the same `(arch, fault map, mitigation, batch)`
//! run through [`repro::chip::SimBackend`] (cycle-level oracle) and
//! [`repro::chip::PlanBackend`] (compiled executor) must produce
//! bit-identical logits — the chip-session-level form of the
//! `proptest_exec.rs` oracle property — plus the capability-rejection
//! story for unsupported (backend, arch) combinations.

use repro::chip::{Backend, Chip, Engine, Scenario};
use repro::faults::{
    inject_uniform, localize_from_map, FaultMap, FaultSpec, StuckAt, TestPatterns,
};
use repro::mapping::MaskKind;
use repro::model::arch::{alexnet32, mnist};
use repro::model::quant::calibrate_mlp;
use repro::model::{Arch, Layer, Params};
use repro::prop_assert;
use repro::util::{prop, Rng};

fn tiny_mlp() -> Arch {
    Arch {
        name: "tiny",
        layers: vec![
            Layer::fc(19, 16, true),
            Layer::fc(16, 11, true),
            Layer::fc(11, 7, false),
        ],
        input_shape: vec![19],
        num_classes: 7,
        eval_batch: 8,
        train_batch: 8,
    }
}

fn rand_params(arch: &Arch, rng: &mut Rng) -> Params {
    let mut p = Params::zeros_like(arch);
    for (w, b) in &mut p.layers {
        w.iter_mut().for_each(|v| *v = rng.normal() * 0.4);
        b.iter_mut().for_each(|v| *v = rng.normal() * 0.05);
    }
    p
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Random faulty chips: Sim and Plan sessions bit-agree on logits under
/// both mitigations, across random array sizes, fault counts and batches.
#[test]
fn prop_sim_plan_logits_bit_identical() {
    let arch = tiny_mlp();
    prop::check("backend_parity_logits", 0xBAC0, 25, |rng| {
        let n = 2 + rng.below(7);
        let faults = rng.below(2 * n);
        let batch = 1 + rng.below(6);
        let kind = if rng.bool(0.5) { MaskKind::Unmitigated } else { MaskKind::FapBypass };
        let params = rand_params(&arch, rng);
        let x: Vec<f32> = (0..batch * arch.input_len()).map(|_| rng.normal()).collect();
        let calib = calibrate_mlp(&arch, &params, &x, batch);

        let chip = Chip::new(arch.clone())
            .array_n(n)
            .inject(faults, rng.next_u64())
            .mitigate(kind)
            .threads(1 + rng.below(4));
        let mut sim = chip.session(Backend::Sim).unwrap();
        let mut plan = chip.session(Backend::Plan).unwrap();
        sim.load_model(params.clone(), calib.clone());
        plan.load_model(params, calib);

        let ls = sim.forward_logits(&x, batch).unwrap();
        let lp = plan.forward_logits(&x, batch).unwrap();
        prop_assert!(
            bits(&ls) == bits(&lp),
            "n={n} faults={faults} batch={batch} kind={kind:?}"
        );
        Ok(())
    });
}

/// Activations (the Fig 2b path) agree bit-for-bit too.
#[test]
fn sim_plan_activations_bit_identical() {
    let arch = tiny_mlp();
    let mut rng = Rng::new(0xAC7);
    let params = rand_params(&arch, &mut rng);
    let batch = 5;
    let x: Vec<f32> = (0..batch * arch.input_len()).map(|_| rng.normal()).collect();
    let calib = calibrate_mlp(&arch, &params, &x, batch);
    let chip = Chip::new(arch.clone()).array_n(4).inject(9, 3);
    let mut sim = chip.session(Backend::Sim).unwrap();
    let mut plan = chip.session(Backend::Plan).unwrap();
    sim.load_model(params.clone(), calib.clone());
    plan.load_model(params, calib);
    let acts_s = sim.activations(&x, batch).unwrap();
    let acts_p = plan.activations(&x, batch).unwrap();
    assert_eq!(acts_s.len(), 3);
    for (li, (s, p)) in acts_s.iter().zip(&acts_p).enumerate() {
        assert_eq!(bits(s), bits(p), "layer {li}");
    }
}

/// Whole-dataset accuracy agrees (same chip, same model, both backends),
/// on the paper's MNIST arch with a real fault map.
#[test]
fn sim_plan_accuracy_identical_on_mnist() {
    let mut arch = mnist();
    arch.eval_batch = 16; // keep the cycle-level oracle affordable in CI
    let mut rng = Rng::new(0x51AB);
    let params = rand_params(&arch, &mut rng);
    // tiny dataset: accuracy equality is about the datapath, not learning
    let n_samples = arch.eval_batch; // one padded batch
    let x: Vec<f32> = (0..n_samples * 784).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..n_samples).map(|_| rng.below(10) as i32).collect();
    let data = repro::data::Dataset::new(x, y, 784, 10);
    let calib = calibrate_mlp(&arch, &params, &data.x[..8 * 784], 8);

    for kind in [MaskKind::Unmitigated, MaskKind::FapBypass] {
        let chip = Chip::new(arch.clone()).array_n(16).inject(24, 7).mitigate(kind);
        let mut sim = chip.session(Backend::Sim).unwrap();
        let mut plan = chip.session(Backend::Plan).unwrap();
        sim.load_model(params.clone(), calib.clone());
        plan.load_model(params.clone(), calib.clone());
        let acc_s = sim.evaluate(&data).unwrap();
        let acc_p = plan.evaluate(&data).unwrap();
        assert_eq!(acc_s, acc_p, "kind {kind:?}");
    }
}

/// Session state survives swap_params coherently on both backends: after
/// the same swap, both still bit-agree (compiled state was invalidated).
#[test]
fn parity_survives_param_swaps() {
    let arch = tiny_mlp();
    let mut rng = Rng::new(0x5AB);
    let p1 = rand_params(&arch, &mut rng);
    let p2 = rand_params(&arch, &mut rng);
    let batch = 4;
    let x: Vec<f32> = (0..batch * arch.input_len()).map(|_| rng.normal()).collect();
    let calib = calibrate_mlp(&arch, &p1, &x, batch);
    let chip = Chip::new(arch.clone()).array_n(5).inject(7, 2).mitigate(MaskKind::FapBypass);
    let mut sim = chip.session(Backend::Sim).unwrap();
    let mut plan = chip.session(Backend::Plan).unwrap();
    sim.load_model(p1.clone(), calib.clone());
    plan.load_model(p1, calib);
    assert_eq!(
        bits(&sim.forward_logits(&x, batch).unwrap()),
        bits(&plan.forward_logits(&x, batch).unwrap())
    );
    sim.swap_params(p2.clone()).unwrap();
    plan.swap_params(p2).unwrap();
    assert_eq!(
        bits(&sim.forward_logits(&x, batch).unwrap()),
        bits(&plan.forward_logits(&x, batch).unwrap())
    );
}

/// Pool determinism: the same chip + seed + model produces bit-identical
/// logits whether the persistent pool runs 1, 2 or N lanes — the
/// serving-stack guarantee that thread budget is a pure throughput knob.
#[test]
fn pool_determinism_same_seed_same_logits_across_thread_counts() {
    let arch = tiny_mlp();
    let mut rng = Rng::new(0x9001);
    let params = rand_params(&arch, &mut rng);
    let batch = 9; // not a multiple of the microkernel tile: edge rows live
    let x: Vec<f32> = (0..batch * arch.input_len()).map(|_| rng.normal()).collect();
    let calib = calibrate_mlp(&arch, &params, &x, batch);
    let chip = Chip::new(arch.clone()).array_n(6).inject(8, 77).mitigate(MaskKind::FapBypass);

    let run = |threads: usize| -> Vec<u32> {
        let mut sess = chip.clone().threads(threads).session(Backend::Plan).unwrap();
        sess.load_model(params.clone(), calib.clone());
        // two forwards through the same session: the persistent pool and
        // reused scratch must not drift between calls either
        let first = bits(&sess.forward_logits(&x, batch).unwrap());
        let second = bits(&sess.forward_logits(&x, batch).unwrap());
        assert_eq!(first, second, "threads={threads}: repeat call drifted");
        first
    };
    let single = run(1);
    for threads in [2usize, 3, 8] {
        assert_eq!(run(threads), single, "threads={threads} diverged from single-thread");
    }

    // and through an Engine (shared spawn-once pool across sessions)
    let mut engine = Engine::new(Backend::Plan, None).unwrap().with_threads(4);
    let mut s1 = engine.session(&chip).unwrap();
    s1.load_model(params.clone(), calib.clone());
    assert_eq!(bits(&s1.forward_logits(&x, batch).unwrap()), single);
    let mut s2 = engine.session(&chip).unwrap();
    s2.load_model(params.clone(), calib.clone());
    assert_eq!(bits(&s2.forward_logits(&x, batch).unwrap()), single);
}

/// Truth-vs-known divergence: a detected chip with an escaped fault must
/// execute the *fabricated* fault map on every backend — Sim and Plan
/// bit-identical to each other, and (because the escaped stuck-at sits on
/// a high accumulator bit) different from a healthy chip. Before the
/// truth/known split, the escaped fault silently stopped existing: the
/// session executed a reconstructed marker map instead of the silicon.
#[test]
fn escaped_fault_executes_truth_on_every_backend() {
    let arch = tiny_mlp();
    let mut rng = Rng::new(0xE5CA);
    let params = rand_params(&arch, &mut rng);
    let batch = 6;
    let x: Vec<f32> = (0..batch * arch.input_len()).map(|_| rng.normal()).collect();
    let calib = calibrate_mlp(&arch, &params, &x, batch);

    // a high-bit stuck-at the controller will never hear about
    let truth = FaultMap::from_faults(
        4,
        [
            StuckAt { row: 1, col: 2, bit: 30, value: true },
            StuckAt { row: 3, col: 0, bit: 29, value: true },
        ],
    );
    for kind in [MaskKind::Unmitigated, MaskKind::FapBypass] {
        let chip = Chip::new(arch.clone())
            .with_fault_map(truth.clone())
            .detect_with(TestPatterns { escape_prob: 1.0, ..Default::default() })
            .unwrap()
            .mitigate(kind);
        assert_eq!(chip.detected(), Some(0), "every fault must escape");
        assert_eq!(chip.escaped_faulty_macs(), 2);

        let mut sim = chip.session(Backend::Sim).unwrap();
        let mut plan = chip.session(Backend::Plan).unwrap();
        sim.load_model(params.clone(), calib.clone());
        plan.load_model(params.clone(), calib.clone());
        assert_eq!(sim.fingerprint(), plan.fingerprint());
        let ls = sim.forward_logits(&x, batch).unwrap();
        let lp = plan.forward_logits(&x, batch).unwrap();
        assert_eq!(bits(&ls), bits(&lp), "kind {kind:?}: Sim/Plan must bit-agree");

        // and the escaped faults are physically present: logits differ
        // from the healthy chip's
        let healthy = Chip::new(arch.clone()).array_n(4).mitigate(kind);
        let mut href = healthy.session(Backend::Plan).unwrap();
        href.load_model(params.clone(), calib.clone());
        let lh = href.forward_logits(&x, batch).unwrap();
        assert_ne!(
            bits(&ls),
            bits(&lh),
            "kind {kind:?}: escaped faults must corrupt the logits"
        );

        // the session identity reflects the controller view too: the same
        // truth under perfect knowledge is a *different* session
        let perfect = Chip::new(arch.clone()).with_fault_map(truth.clone()).mitigate(kind);
        let psess = perfect.session(Backend::Plan).unwrap();
        assert_ne!(psess.fingerprint(), plan.fingerprint(), "kind {kind:?}");
    }
}

/// SIMD dispatch parity on a faulty chip with escaped faults: whatever
/// kernel [`repro::exec::kernel`] resolved on this host (AVX2, NEON or
/// the scalar fallback), Sim and Plan logits stay bit-identical — the
/// array size (9) and tiny_mlp dims force partial tiles and tail panels,
/// and the stuck-ats sit on the array's last columns so the FAP bypass
/// masks land exactly where the zero-padded tail lanes live.
#[test]
fn simd_dispatch_sim_plan_parity_with_escaped_faults() {
    let isa = repro::exec::kernel().isa();
    let arch = tiny_mlp();
    let mut rng = Rng::new(0x51D0);
    let params = rand_params(&arch, &mut rng);
    let batch = 7; // not a multiple of MICRO_MR: edge-row kernel is live
    let x: Vec<f32> = (0..batch * arch.input_len()).map(|_| rng.normal()).collect();
    let calib = calibrate_mlp(&arch, &params, &x, batch);

    // faults on the last columns of a 9-wide array: the bypass mask (when
    // localized) and the escaped corruption (when not) both sit in the
    // final, partially-filled weight panel of each tile row
    let truth = FaultMap::from_faults(
        9,
        [
            StuckAt { row: 2, col: 8, bit: 27, value: true },
            StuckAt { row: 5, col: 7, bit: 29, value: true },
            StuckAt { row: 7, col: 8, bit: 4, value: true },
        ],
    );
    for escape_prob in [0.0, 1.0] {
        for kind in [MaskKind::Unmitigated, MaskKind::FapBypass] {
            let chip = Chip::new(arch.clone())
                .with_fault_map(truth.clone())
                .detect_with(TestPatterns { escape_prob, ..Default::default() })
                .unwrap()
                .mitigate(kind);
            let mut sim = chip.session(Backend::Sim).unwrap();
            let mut plan = chip.session(Backend::Plan).unwrap();
            sim.load_model(params.clone(), calib.clone());
            plan.load_model(params.clone(), calib.clone());
            let ls = sim.forward_logits(&x, batch).unwrap();
            let lp = plan.forward_logits(&x, batch).unwrap();
            assert_eq!(
                bits(&ls),
                bits(&lp),
                "isa={isa:?} escape_prob={escape_prob} kind={kind:?}: \
                 dispatched kernel diverged from the cycle-level sim"
            );
        }
    }
}

/// Under forced escapes the detected set is always a subset of the truth
/// (never a false positive), detection is deterministic per test program,
/// and escape_prob = 0 recovers full recall.
#[test]
fn prop_detect_report_subset_of_truth_under_escapes() {
    prop::check("detect_escape_subset", 0xE5C2, 30, |rng| {
        let n = 4 + rng.below(13);
        let faults = 1 + rng.below(2 * n);
        let truth = inject_uniform(FaultSpec::new(n), faults, &mut Rng::new(rng.next_u64()));
        let truth_macs = truth.faulty_macs();
        let p = rng.f64();
        let cfg = TestPatterns { escape_prob: p, seed: rng.next_u64(), ..Default::default() };
        let rep = localize_from_map(&truth, cfg);
        prop_assert!(rep.faulty.len() <= truth_macs.len(), "n={n} p={p}");
        for f in &rep.faulty {
            prop_assert!(truth_macs.contains(f), "false positive at {f:?} (n={n} p={p})");
        }
        // deterministic per test program
        let rep2 = localize_from_map(&truth, cfg);
        prop_assert!(rep.faulty == rep2.faulty, "detection must be deterministic");
        // exhaustive coverage recovers everything
        let full = localize_from_map(&truth, TestPatterns { escape_prob: 0.0, ..cfg });
        prop_assert!(full.faulty == truth_macs, "p=0 must reach full recall");
        Ok(())
    });
}

/// Capability rejection: the matrix lives in `Backend::supports` and the
/// session builder enforces it for every unsupported (backend, arch) pair.
#[test]
fn unsupported_backend_arch_combos_rejected() {
    let conv = alexnet32();
    let chip = Chip::new(conv.clone()).array_n(8).inject(5, 1);
    for backend in [Backend::Sim, Backend::Plan] {
        let err = chip.session(backend).unwrap_err().to_string();
        assert!(err.contains("conv layers"), "{backend:?}: {err}");
    }
    // xla: float/train fine, faulty chip path rejected
    assert!(Backend::Xla.supports(&conv, Scenario::FloatFwd).is_ok());
    assert!(Backend::Xla.supports(&conv, Scenario::Train).is_ok());
    assert!(Backend::Xla.supports(&conv, Scenario::FaultyFwd).is_err());
    // native engines cannot train conv archs either
    let engine = Engine::new(Backend::Plan, None).unwrap();
    let (train, _) = repro::data::for_arch("alexnet32", 64, 32, 1).unwrap();
    let cfg = repro::coordinator::trainer::TrainConfig { steps: 1, ..Default::default() };
    assert!(engine.train(&conv, &train, &cfg).is_err());
    // xla sessions without a runtime are impossible to build
    assert!(Chip::new(mnist()).session(Backend::Xla).is_err());
    assert!(Engine::new(Backend::Xla, None).is_err());
}
