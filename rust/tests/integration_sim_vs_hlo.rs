//! Cross-check: the rust cycle-level systolic simulator and the AOT HLO
//! faulty-matmul artifact implement the *same* datapath, bit for bit.
//!
//! This is the keystone consistency test of the reproduction: the L1
//! Pallas kernel, the pure-jnp oracle (pytest), the lax.scan graph and the
//! rust PE-grid simulator must all agree on the stuck-at semantics.

use repro::faults::{FaultMap, StuckAt};
use repro::runtime::{lit_i32, Runtime};
use repro::systolic::TiledMatmul;
use repro::util::Rng;

fn artifacts_dir() -> String {
    std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// The faulty_matmul_test artifact has fixed geometry (see aot.py):
/// a[8,24] x w[24,16], array_rows = 8. The physical array is the square
/// 8x8 grid, so logical weight (k, n) maps to MAC (k % 8, n % 8) and the
/// 16 output columns run as two column tiles.
const B: usize = 8;
const K: usize = 24;
const N: usize = 16;
const AN: usize = 8; // physical array dimension

fn random_case(
    seed: u64,
    n_faults: usize,
    n_bypass: usize,
) -> (Vec<i32>, Vec<i32>, FaultMap, Vec<(usize, usize)>) {
    let mut rng = Rng::new(seed);
    let a: Vec<i32> = (0..B * K).map(|_| rng.below(255) as i32 - 127).collect();
    let w: Vec<i32> = (0..K * N).map(|_| rng.below(255) as i32 - 127).collect();
    let mut fm = FaultMap::healthy(AN);
    for _ in 0..n_faults {
        fm.add(StuckAt {
            row: rng.below(AN) as u16,
            col: rng.below(AN) as u16,
            bit: rng.below(32) as u8,
            value: rng.bool(0.5),
        });
    }
    let mut bypass = Vec::new();
    for _ in 0..n_bypass {
        bypass.push((rng.below(AN), rng.below(AN)));
    }
    (a, w, fm, bypass)
}

/// Expand physical fault map + bypass list to logical [K][N] mask arrays
/// (what the artifact takes as inputs), using the paper's mapping
/// r = k mod AN, c = n mod AN.
fn logical_masks(fm: &FaultMap, bypass: &[(usize, usize)]) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    let mut and_m = vec![-1i32; K * N];
    let mut or_m = vec![0i32; K * N];
    let mut byp = vec![0i32; K * N];
    for k in 0..K {
        for n in 0..N {
            let (r, c) = (k % AN, n % AN);
            and_m[k * N + n] = fm.and_at(r, c);
            or_m[k * N + n] = fm.or_at(r, c);
            if bypass.contains(&(r, c)) {
                byp[k * N + n] = 1;
            }
        }
    }
    (and_m, or_m, byp)
}

fn run_hlo(
    exe: &repro::runtime::Executable,
    a: &[i32],
    w: &[i32],
    masks: &(Vec<i32>, Vec<i32>, Vec<i32>),
) -> Vec<i32> {
    let inputs = vec![
        lit_i32(a, &[B, K]).unwrap(),
        lit_i32(w, &[K, N]).unwrap(),
        lit_i32(&masks.0, &[K, N]).unwrap(),
        lit_i32(&masks.1, &[K, N]).unwrap(),
        lit_i32(&masks.2, &[K, N]).unwrap(),
    ];
    let outs = exe.run(&inputs).unwrap();
    exe.i32_out(&outs, 0).unwrap()
}

#[test]
fn simulator_matches_hlo_artifact_bit_for_bit() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let exe = rt.load("faulty_matmul_test").unwrap();

    for case in 0..12u64 {
        let n_faults = (case % 5) as usize * 2;
        let n_bypass = (case % 3) as usize;
        let (a, w, fm, bypass) = random_case(1000 + case, n_faults, n_bypass);
        let masks = logical_masks(&fm, &bypass);
        let hlo = run_hlo(&exe, &a, &w, &masks);

        let mut tm = TiledMatmul::new(&fm, false);
        for &(r, c) in &bypass {
            tm.array_mut().pe_mut(r, c).bypass = true;
        }
        let sim = tm.matmul(&a, &w, B, K, N);
        assert_eq!(sim, hlo, "case {case}: simulator != HLO artifact");
    }
}

#[test]
fn tiled_matmul_fap_matches_hlo_with_bypass_everywhere_faulty() {
    // FAP scenario: every faulty MAC bypassed on both paths.
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let exe = rt.load("faulty_matmul_test").unwrap();
    let (a, w, fm, _) = random_case(77, 6, 0);
    let bypass = fm.faulty_macs();
    let masks = logical_masks(&fm, &bypass);
    let hlo = run_hlo(&exe, &a, &w, &masks);

    let mut tm = TiledMatmul::new(&fm, true); // FAP bypass on
    let sim = tm.matmul(&a, &w, B, K, N);
    assert_eq!(sim, hlo, "FAP bypass: simulator != HLO artifact");

    // and both equal the pruned plain matmul (healthy-array semantics)
    let mut wp = w.clone();
    for k in 0..K {
        for n in 0..N {
            if fm.is_faulty(k % AN, n % AN) {
                wp[k * N + n] = 0;
            }
        }
    }
    let mut healthy = TiledMatmul::new(&FaultMap::healthy(AN), false);
    let pruned = healthy.matmul(&a, &wp, B, K, N);
    assert_eq!(sim, pruned, "FAP != pruned weights on healthy array");
}
