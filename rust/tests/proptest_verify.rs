//! Property tests for the static plan verifier ([`repro::analysis`]):
//! every plan the compiler produces — any shape, fault map, controller
//! view, mitigation, panel width or panel element type — must verify
//! with zero diagnostics. This is the acceptance half of the verifier's
//! contract; the rejection half (seeded mutations must be caught with
//! the right rule ids) lives in the crate's unit tests, which can reach
//! the IR mutation hooks.
//!
//! Uses the in-repo harness (`rust/src/util/prop.rs`; the offline
//! registry has no proptest). Failing cases replay with
//! `PROP_REPLAY=<seed>`.

use repro::analysis::verify::{verify_chip_plan, verify_layer_masks, verify_matmul_plan};
use repro::exec::{MatmulPlan, PanelOptions};
use repro::faults::{inject_uniform, FaultSpec, KnownMap};
use repro::mapping::{LayerMasks, MaskKind};
use repro::model::arch;
use repro::prop_assert;
use repro::util::{prop, Rng};

/// Random `(truth, known)` pair: uniform stuck-at faults plus a
/// controller view that is the truth, a subset of it (escapes), or a
/// superset-shaped independent detection (false positives are legal —
/// bypassing a healthy column only costs accuracy, never correctness).
fn random_views(rng: &mut Rng, n: usize, max_faults: usize) -> (repro::faults::FaultMap, KnownMap) {
    let faults = rng.below(max_faults.min(n * n) + 1);
    let truth = inject_uniform(FaultSpec::new(n), faults, &mut Rng::new(rng.next_u64()));
    let known = match rng.below(3) {
        0 => KnownMap::perfect(&truth),
        1 => KnownMap::from_macs(
            n,
            truth.faulty_macs().into_iter().filter(|_| rng.bool(0.6)),
        ),
        _ => {
            let mut macs = truth.faulty_macs();
            for _ in 0..rng.below(4) {
                macs.push((rng.below(n), rng.below(n)));
            }
            KnownMap::from_macs(n, macs)
        }
    };
    (truth, known)
}

fn kind_of(rng: &mut Rng) -> MaskKind {
    if rng.bool(0.5) {
        MaskKind::FapBypass
    } else {
        MaskKind::Unmitigated
    }
}

/// Every compiler-produced tile program verifies clean, across random
/// shapes (partial tiles included), views, panel widths and both panel
/// element types.
#[test]
fn prop_compiled_matmul_plans_verify_clean() {
    prop::check("verifier_accepts_compiled_plans", 0x5AFE, 80, |rng| {
        let n = 2 + rng.below(9);
        // bias toward non-multiples of n: partial-height and
        // partial-width tiles (the C1 tail-lane surface) are the
        // common case
        let k = 1 + rng.below(3 * n);
        let m = 1 + rng.below(3 * n);
        let (truth, known) = random_views(rng, n, 8);
        let kind = kind_of(rng);
        let mut w: Vec<i32> = (0..k * m).map(|_| rng.below(255) as i32 - 127).collect();
        // exact zeros exercise the dense additive-constant fold path
        for v in w.iter_mut() {
            if rng.bool(0.15) {
                *v = 0;
            }
        }
        let nr = if rng.bool(0.5) { 4 } else { 8 };
        let allow_i8 = rng.bool(0.5);
        let plan = MatmulPlan::compile_views_opts(
            &truth,
            &known,
            kind,
            &w,
            k,
            m,
            PanelOptions { nr, allow_i8 },
        );
        let diags = verify_matmul_plan(&plan, &truth, &known, &w);
        prop_assert!(
            diags.is_empty(),
            "{k}x{m} on {n}x{n} ({kind:?}, {} faults, {} known, nr {nr}, i8 {allow_i8}) \
             raised: {}",
            truth.faulty_mac_count(),
            known.faulty_mac_count(),
            diags[0]
        );
        Ok(())
    });
}

#[test]
fn prop_compiled_layer_masks_verify_clean() {
    prop::check("verifier_accepts_built_masks", 0xA11, 40, |rng| {
        let n = 4 + rng.below(13);
        let (truth, known) = random_views(rng, n, n * n / 6);
        let kind = kind_of(rng);
        for model in ["mnist", "timit", "alexnet32"] {
            let a = arch::by_name(model).unwrap();
            let masks = LayerMasks::build_views(&a, &truth, &known, kind);
            let diags = verify_layer_masks(&a, &masks, &truth, &known, kind);
            prop_assert!(
                diags.is_empty(),
                "masks for {model} ({kind:?}, {} faults, {} known) raised: {}",
                truth.faulty_mac_count(),
                known.faulty_mac_count(),
                diags[0]
            );
        }
        Ok(())
    });
}

/// Whole-chip acceptance: the quantized-MLP lowering path verifies
/// clean end to end (identity, host masks, and every per-layer tile
/// program against the quantized weights it was compiled from).
#[test]
fn prop_compiled_chip_plans_verify_clean() {
    prop::check("verifier_accepts_chip_plans", 0xC41, 12, |rng| {
        let n = 4 + rng.below(13);
        let (truth, known) = random_views(rng, n, n * n / 6);
        let kind = kind_of(rng);
        let a = arch::mnist();
        let qweights: Vec<Vec<i32>> = a
            .weighted_layers()
            .iter()
            .map(|l| (0..l.weight_len()).map(|_| rng.below(255) as i32 - 127).collect())
            .collect();
        let plan = repro::exec::ChipPlan::compile_mlp_views(&a, &truth, &known, kind, &qweights);
        let diags = verify_chip_plan(&plan, &a, &truth, &known, Some(&qweights));
        prop_assert!(
            diags.is_empty(),
            "chip plan ({kind:?}, {} faults, {} known) raised: {}",
            truth.faulty_mac_count(),
            known.faulty_mac_count(),
            diags[0]
        );
        Ok(())
    });
}
