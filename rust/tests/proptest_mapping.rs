//! Property tests over the weight↔MAC mapping and mask synthesis.

use repro::faults::{inject_uniform, FaultMap, FaultSpec, StuckAt};
use repro::mapping::{conv_mac_of, fc_mac_of, LayerMasks, MaskKind};
use repro::model::arch;
use repro::prop_assert;
use repro::util::{prop, Rng};

fn random_fault_map(rng: &mut Rng, n: usize, max_faults: usize) -> FaultMap {
    let k = rng.below(max_faults + 1).min(n * n);
    inject_uniform(FaultSpec::new(n), k, rng)
}

/// Every pruned weight maps to a faulty MAC and vice versa (FC layers).
#[test]
fn prop_fc_prune_mask_iff_faulty() {
    prop::check("fc_prune_iff_faulty", 0xB1, 30, |rng| {
        let n = 2 + rng.below(16);
        let fm = random_fault_map(rng, n, 12);
        let din = 1 + rng.below(60);
        let dout = 1 + rng.below(60);
        let mask = repro::mapping::fc_prune_mask(&fm, din, dout);
        for k in 0..din {
            for j in 0..dout {
                let (r, c) = fc_mac_of(k, j, n);
                let pruned = mask[k * dout + j] == 0.0;
                prop_assert!(
                    pruned == fm.is_faulty(r, c),
                    "({k},{j}) -> MAC ({r},{c}): pruned={pruned}, faulty={}",
                    fm.is_faulty(r, c)
                );
            }
        }
        Ok(())
    });
}

/// Conv masks are tap-uniform: mask value is identical across all (ky,kx)
/// for a given channel pair — the paper's whole-channel pruning.
#[test]
fn prop_conv_mask_tap_uniform() {
    prop::check("conv_mask_tap_uniform", 0xB2, 25, |rng| {
        let n = 2 + rng.below(12);
        let fm = random_fault_map(rng, n, 10);
        let (kh, kw) = (1 + rng.below(5), 1 + rng.below(5));
        let din = 1 + rng.below(24);
        let dout = 1 + rng.below(24);
        let mask = repro::mapping::conv_prune_mask(&fm, kh, kw, din, dout);
        for di in 0..din {
            for do_ in 0..dout {
                let v0 = mask[di * dout + do_];
                for t in 1..kh * kw {
                    prop_assert!(
                        mask[t * din * dout + di * dout + do_] == v0,
                        "tap {t} differs at channel pair ({di},{do_})"
                    );
                }
                let (r, c) = conv_mac_of(di, do_, n);
                prop_assert!((v0 == 0.0) == fm.is_faulty(r, c), "channel ({di},{do_})");
            }
        }
        Ok(())
    });
}

/// LayerMasks invariants across a whole architecture: prune ⟺ bypass
/// (FAP), and the int fault masks agree with the physical map.
#[test]
fn prop_layer_masks_consistent() {
    prop::check("layer_masks_consistent", 0xB3, 12, |rng| {
        let n = 4 + rng.below(29);
        let fm = random_fault_map(rng, n, 20);
        let a = match rng.below(3) {
            0 => arch::mnist(),
            1 => arch::timit(false),
            _ => arch::alexnet32(),
        };
        let m = LayerMasks::build(&a, &fm, MaskKind::FapBypass);
        prop_assert!(m.prune.len() == a.num_weighted(), "mask count");
        for l in 0..m.prune.len() {
            for i in 0..m.prune[l].len() {
                let pruned = m.prune[l][i] == 0.0;
                let bypassed = m.bypass[l][i] == 1;
                let faulty = m.and_m[l][i] != -1 || m.or_m[l][i] != 0;
                prop_assert!(pruned == bypassed, "layer {l} idx {i}: prune vs bypass");
                prop_assert!(pruned == faulty, "layer {l} idx {i}: prune vs fault mask");
            }
        }
        Ok(())
    });
}

/// Pruned fraction for dimension-aligned layers equals the fault rate
/// exactly; unaligned layers stay within a mask-period bound.
#[test]
fn prop_pruned_fraction_bounds() {
    prop::check("pruned_fraction_bounds", 0xB4, 20, |rng| {
        let n = 2 + rng.below(14);
        let fm = random_fault_map(rng, n, n * n / 2);
        let rate = fm.fault_rate();
        // aligned: multiples of n
        let din = n * (1 + rng.below(4));
        let dout = n * (1 + rng.below(4));
        let frac = repro::mapping::fc::fc_pruned_fraction(&fm, din, dout);
        prop_assert!((frac - rate).abs() < 1e-9, "aligned frac {frac} != rate {rate}");
        Ok(())
    });
}

/// Masks are deterministic functions of the fault map.
#[test]
fn prop_masks_deterministic() {
    prop::check("masks_deterministic", 0xB5, 10, |rng| {
        let n = 2 + rng.below(12);
        let mut faults = Vec::new();
        for _ in 0..rng.below(8) {
            faults.push(StuckAt {
                row: rng.below(n) as u16,
                col: rng.below(n) as u16,
                bit: rng.below(32) as u8,
                value: rng.bool(0.5),
            });
        }
        let fm1 = FaultMap::from_faults(n, faults.clone());
        let fm2 = FaultMap::from_faults(n, faults);
        let a = arch::mnist();
        let m1 = LayerMasks::build(&a, &fm1, MaskKind::FapBypass);
        let m2 = LayerMasks::build(&a, &fm2, MaskKind::FapBypass);
        prop_assert!(m1.prune == m2.prune, "prune masks differ");
        prop_assert!(m1.and_m == m2.and_m && m1.or_m == m2.or_m, "fault masks differ");
        Ok(())
    });
}
