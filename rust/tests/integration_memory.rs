//! Memory-stability regression test for the PJRT execute path.
//!
//! The upstream xla crate's C shim leaked one full input-buffer set per
//! `execute` call (~20 MB per 1.5M-param train step — the original full
//! experiment campaign OOM-killed a 36 GB box). We patched the vendored
//! shim (vendor/xla/xla_rs/xla_rs.cc, see "[repro patch]"); this test
//! pins the fix: RSS growth across many train steps must stay bounded.

use repro::coordinator::trainer::{ones_masks, train_step, TrainState};
use repro::model::arch;
use repro::runtime::Runtime;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for l in s.lines() {
        if let Some(rest) = l.strip_prefix("VmRSS:") {
            return rest.trim().split_whitespace().next().unwrap().parse::<f64>().unwrap()
                / 1024.0;
        }
    }
    0.0
}

#[test]
fn execute_path_does_not_leak_input_buffers() {
    let rt = Runtime::new(
        std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )
    .unwrap();
    let a = arch::by_name("timit").unwrap();
    let exe = rt.load("timit_train").unwrap();
    let mut state = TrainState::init(&rt, &a, 1).unwrap();
    let masks = ones_masks(&a).unwrap();
    let x = vec![0.1f32; a.train_batch * a.input_len()];
    let y = vec![0i32; a.train_batch];
    let dims = [a.train_batch, a.input_len()];

    // warm up allocator + executable state
    for _ in 0..5 {
        train_step(&exe, &mut state, &masks, &x, &y, &dims, 0.01).unwrap();
    }
    let before = rss_mb();
    let steps = 40;
    for _ in 0..steps {
        train_step(&exe, &mut state, &masks, &x, &y, &dims, 0.01).unwrap();
    }
    let after = rss_mb();
    let growth = after - before;
    // unpatched shim leaked ~19 MB/step (~760 MB over 40 steps); allow a
    // generous allocator-noise budget of 150 MB total
    assert!(
        growth < 150.0,
        "RSS grew {growth:.0} MB over {steps} steps ({before:.0} -> {after:.0}): \
         the execute input-buffer leak is back"
    );
}
