//! Fleet serving end-to-end: scheduler conservation (every request routed
//! exactly once, on every policy), deterministic routing in the seed, and
//! the acceptance scenario — a FAP+T-managed fleet beats an unmitigated
//! fleet on served accuracy when aging drives chips to a 25% end-of-life
//! fault rate.

use repro::chip::{Backend, Chip, Engine};
use repro::coordinator::trainer::{train_baseline_native, TrainConfig};
use repro::data::Dataset;
use repro::fleet::{
    fleet_json, provision_fleet, run_lifetime, serve, ChipUnit, FleetConfig, RoutingPolicy,
    WorkloadConfig, YieldDist,
};
use repro::mapping::MaskKind;
use repro::model::quant::{calibrate_mlp, Calibration};
use repro::model::{Arch, Layer, Params};
use repro::util::Rng;

fn tiny_arch() -> Arch {
    Arch {
        name: "tiny",
        layers: vec![Layer::fc(12, 16, true), Layer::fc(16, 4, false)],
        input_shape: vec![12],
        num_classes: 4,
        eval_batch: 16,
        train_batch: 16,
    }
}

/// Four well-separated gaussian clusters in 12-D: a task the tiny MLP
/// learns to near-100% in a few hundred steps, so accuracy deltas under
/// faults are attributable to the chip, not the task.
fn clustered(n: usize, seed: u64) -> Dataset {
    let mut crng = Rng::new(77); // centers shared across train/test
    let centers: Vec<Vec<f32>> =
        (0..4).map(|_| (0..12).map(|_| crng.normal() * 2.0).collect()).collect();
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * 12);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % 4;
        y.push(c as i32);
        for d in 0..12 {
            x.push(centers[c][d] + rng.normal() * 0.5);
        }
    }
    Dataset::new(x, y, 12, 4)
}

fn bundle() -> (Arch, Params, Calibration, Dataset, Dataset) {
    let arch = tiny_arch();
    let train = clustered(320, 1);
    let test = clustered(160, 2);
    let cfg = TrainConfig { steps: 300, seed: 5, ..Default::default() };
    let (golden, _) = train_baseline_native(&arch, &train, &cfg).unwrap();
    let calib = calibrate_mlp(&arch, &golden, &train.x[..64 * 12], 64);
    (arch, golden, calib, train, test)
}

#[test]
fn scheduler_routes_every_request_exactly_once() {
    let (arch, golden, calib, _train, test) = bundle();
    let chips: Vec<Chip> = (0..3)
        .map(|i| {
            Chip::new(arch.clone())
                .array_n(8)
                .inject(4 + i, 100 + i as u64)
                .detect()
                .unwrap()
                .mitigate(MaskKind::FapBypass)
                .threads(1)
        })
        .collect();
    let requests = 40usize;
    for policy in
        [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded, RoutingPolicy::AccuracyWeighted]
    {
        let units: Vec<ChipUnit<'_>> = chips
            .iter()
            .enumerate()
            .map(|(i, c)| {
                ChipUnit { id: i, chip: c, params: &golden, weight: 0.5 + 0.1 * i as f64 }
            })
            .collect();
        let cfg = WorkloadConfig {
            backend: Backend::Plan,
            policy,
            batch: 8,
            queue_depth: 2,
            requests,
            workers: 2,
            seed: 9,
        };
        let rep = serve(&units, &calib, &test, &cfg).unwrap();
        // conservation: every request id served exactly once, fleet-wide
        let mut ids: Vec<usize> =
            rep.per_chip.iter().flat_map(|c| c.request_ids.iter().copied()).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..requests).collect::<Vec<_>>(), "policy {policy:?}");
        assert_eq!(rep.requests, requests);
        assert_eq!(rep.samples, requests * 8);
        assert!(rep.sim_cycles > 0);
        assert_eq!(rep.per_chip.len(), 3);
        if policy == RoutingPolicy::RoundRobin {
            for c in &rep.per_chip {
                let k = c.request_ids.len();
                assert!(k == 13 || k == 14, "round-robin imbalance: {k}");
            }
        }
    }
}

#[test]
fn round_robin_serving_is_deterministic_in_seed() {
    let (arch, golden, calib, _train, test) = bundle();
    let chip =
        Chip::new(arch.clone()).array_n(8).inject(5, 42).detect().unwrap().threads(1);
    let chips = [chip.clone(), chip.mitigate(MaskKind::FapBypass)];
    let run = || {
        let units: Vec<ChipUnit<'_>> = chips
            .iter()
            .enumerate()
            .map(|(i, c)| ChipUnit { id: i, chip: c, params: &golden, weight: 1.0 })
            .collect();
        let cfg = WorkloadConfig {
            backend: Backend::Plan,
            policy: RoutingPolicy::RoundRobin,
            batch: 8,
            queue_depth: 2,
            requests: 24,
            workers: 2,
            seed: 33,
        };
        serve(&units, &calib, &test, &cfg).unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.correct, b.correct, "same seed must serve the same traffic");
    assert_eq!(a.samples, b.samples);
    for (ca, cb) in a.per_chip.iter().zip(&b.per_chip) {
        let (mut ia, mut ib) = (ca.request_ids.clone(), cb.request_ids.clone());
        ia.sort_unstable();
        ib.sort_unstable();
        assert_eq!(ia, ib, "chip {} routing changed across runs", ca.chip_id);
        assert_eq!(ca.correct, cb.correct);
    }
}

/// The acceptance scenario: at a 25% end-of-life fault rate, the FAP+T
/// health-managed fleet must serve measurably better accuracy over its
/// life than the unmitigated fleet (same chips, same traffic, same seed).
#[test]
fn managed_fleet_beats_unmitigated_at_25pct_eol() {
    let (arch, golden, calib, train, test) = bundle();
    let base = FleetConfig {
        chips: 4,
        array_n: 8,
        seed: 11,
        policy: RoutingPolicy::RoundRobin,
        hours: 20_000.0,
        life_steps: 3,
        yield_dist: YieldDist::Fixed(2),
        eol_fault_rate: 0.25,
        aging_beta: 2.0,
        slo_frac: 0.85,
        batch: 16,
        queue_depth: 2,
        batches_per_chip: 2,
        workers: 2,
        retrain_epochs: 2,
        retrain_downtime_hours: 100.0,
        max_retrains: 4,
        managed: true,
        escape_prob: 0.0,
    };
    let run = |managed: bool| {
        let mut engine = Engine::new(Backend::Plan, None).unwrap();
        let cfg = FleetConfig { managed, ..base.clone() };
        let mut fleet =
            provision_fleet(&mut engine, cfg, &arch, &golden, &calib, &train, &test).unwrap();
        let out = run_lifetime(&mut engine, &mut fleet, &golden, &train, &test).unwrap();
        (fleet, out)
    };
    let (mfleet, mout) = run(true);
    let (ufleet, uout) = run(false);

    // the unmitigated fleet (never retired, ages the full life) really is
    // at ~25% faulty MACs by end of life — the scenario under test
    for c in &ufleet.chips {
        let r = c.aging.fault_rate();
        assert!(r > 0.15, "chip {} only aged to {r:.2} fault rate", c.id);
    }
    assert!(mout.total_samples > 0 && uout.total_samples > 0);
    let (ma, ua) = (mout.served_accuracy(), uout.served_accuracy());
    assert!(
        ma > ua + 0.05,
        "FAP+T health management ({ma:.3}) must beat unmitigated ({ua:.3})"
    );

    // the JSON record carries the headline fields the campaign promises
    let json = fleet_json(&mfleet, &mout, "plan").render();
    for key in [
        "\"fleet_accuracy\"",
        "\"samples_per_sec\"",
        "\"p50_batch_latency_us\"",
        "\"p99_batch_latency_us\"",
        "\"effective_yield\"",
        "\"retrain_events\"",
        "\"sim_cycles\"",
        "\"escape_prob\"",
        "\"sdc_samples\"",
        "\"sdc_fraction\"",
        "\"escaped_faulty_macs\"",
    ] {
        assert!(json.contains(key), "fleet.json missing {key}");
    }
    // at escape_prob 0 localization is (near-)exhaustive: SDC exposure
    // stays a sliver of the served traffic, not a systematic leak
    assert!(
        mout.sdc_fraction() < 0.5,
        "unexpected SDC exposure without forced escapes: {}",
        mout.sdc_fraction()
    );

    // the blind fleet is the opposite pole: its controller never ran
    // localization, so with every chip fabbed faulty (Fixed(2) defects)
    // all of its served traffic is SDC-exposed — the view must not
    // default to perfect knowledge and report zero escapes
    for c in &ufleet.chips {
        assert_eq!(c.known_faulty_macs(), 0, "blind chip {} must know nothing", c.id);
        assert!(c.escaped_faulty_macs() >= 2, "blind chip {} hides its defects", c.id);
    }
    assert_eq!(uout.sdc_samples, uout.total_samples, "blind fleet must be fully SDC-exposed");
    assert!((uout.sdc_fraction() - 1.0).abs() < 1e-12);
}

/// Escaped-fault SDC accounting: when every fault escapes the health
/// monitor's localization, the managed fleet believes its chips clean,
/// prunes nothing, and every served sample is exposed to silent data
/// corruption — which `fleet.json` must report alongside served accuracy.
#[test]
fn escaped_faults_are_accounted_as_sdc_traffic() {
    let (arch, golden, calib, train, test) = bundle();
    let cfg = FleetConfig {
        chips: 3,
        array_n: 8,
        seed: 21,
        policy: RoutingPolicy::RoundRobin,
        hours: 10_000.0,
        life_steps: 2,
        yield_dist: YieldDist::Fixed(2),
        eol_fault_rate: 0.2,
        aging_beta: 2.0,
        // SLO low enough that corrupted chips keep serving: the scenario
        // is about exposure accounting, not retirement
        slo_frac: 0.05,
        batch: 16,
        queue_depth: 2,
        batches_per_chip: 2,
        workers: 2,
        retrain_epochs: 1,
        retrain_downtime_hours: 50.0,
        max_retrains: 2,
        managed: true,
        escape_prob: 1.0,
    };
    let mut engine = Engine::new(Backend::Plan, None).unwrap();
    let mut fleet =
        provision_fleet(&mut engine, cfg, &arch, &golden, &calib, &train, &test).unwrap();
    let out = run_lifetime(&mut engine, &mut fleet, &golden, &train, &test).unwrap();

    assert!(out.total_samples > 0, "fleet must have served traffic");
    // every chip fabbed with 2 defects and escape_prob 1.0: the
    // controller never detects anything, so all traffic is SDC-exposed
    for c in &fleet.chips {
        assert_eq!(c.known_faulty_macs(), 0, "chip {}: nothing must be detected", c.id);
        assert!(c.escaped_faulty_macs() >= 2, "chip {}: fab defects must escape", c.id);
        assert_eq!(c.sdc_samples, c.served_samples, "chip {}", c.id);
    }
    assert_eq!(out.sdc_samples, out.total_samples);
    assert!((out.sdc_fraction() - 1.0).abs() < 1e-12);
    assert!(out.escaped_faults_eol >= 3 * 2);
    let json = fleet_json(&fleet, &out, "plan").render();
    assert!(json.contains("\"escape_prob\": 1"), "missing escape_prob: {json}");
}
