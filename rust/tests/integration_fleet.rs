//! Fleet serving end-to-end: scheduler conservation (every request routed
//! exactly once, on every policy, closed and open loop), deterministic
//! serving in the seed, open-loop admission accounting under overload, and
//! the acceptance scenario — a FAP+T-managed fleet beats an unmitigated
//! fleet on served accuracy when aging drives chips to a 25% end-of-life
//! fault rate.

use repro::chip::{Backend, Chip, Engine};
use repro::coordinator::trainer::{train_baseline_native, TrainConfig};
use repro::data::Dataset;
use repro::fleet::{
    fleet_json, provision_fleet, run_lifetime, serve, serve_open, ArrivalProcess, BatcherConfig,
    ChipUnit, FleetConfig, OpenWorkloadConfig, RequestOutcome, RoutingPolicy, WorkloadConfig,
    WrrPicker, YieldDist,
};
use repro::mapping::MaskKind;
use repro::model::quant::{calibrate_mlp, Calibration};
use repro::model::{Arch, Layer, Params};
use repro::prop_assert;
use repro::util::prop;
use repro::util::Rng;

fn tiny_arch() -> Arch {
    Arch {
        name: "tiny",
        layers: vec![Layer::fc(12, 16, true), Layer::fc(16, 4, false)],
        input_shape: vec![12],
        num_classes: 4,
        eval_batch: 16,
        train_batch: 16,
    }
}

/// Four well-separated gaussian clusters in 12-D: a task the tiny MLP
/// learns to near-100% in a few hundred steps, so accuracy deltas under
/// faults are attributable to the chip, not the task.
fn clustered(n: usize, seed: u64) -> Dataset {
    let mut crng = Rng::new(77); // centers shared across train/test
    let centers: Vec<Vec<f32>> =
        (0..4).map(|_| (0..12).map(|_| crng.normal() * 2.0).collect()).collect();
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * 12);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % 4;
        y.push(c as i32);
        for d in 0..12 {
            x.push(centers[c][d] + rng.normal() * 0.5);
        }
    }
    Dataset::new(x, y, 12, 4)
}

fn bundle() -> (Arch, Params, Calibration, Dataset, Dataset) {
    let arch = tiny_arch();
    let train = clustered(320, 1);
    let test = clustered(160, 2);
    let cfg = TrainConfig { steps: 300, seed: 5, ..Default::default() };
    let (golden, _) = train_baseline_native(&arch, &train, &cfg).unwrap();
    let calib = calibrate_mlp(&arch, &golden, &train.x[..64 * 12], 64);
    (arch, golden, calib, train, test)
}

#[test]
fn scheduler_routes_every_request_exactly_once() {
    let (arch, golden, calib, _train, test) = bundle();
    let chips: Vec<Chip> = (0..3)
        .map(|i| {
            Chip::new(arch.clone())
                .array_n(8)
                .inject(4 + i, 100 + i as u64)
                .detect()
                .unwrap()
                .mitigate(MaskKind::FapBypass)
                .threads(1)
        })
        .collect();
    let requests = 40usize;
    for policy in
        [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded, RoutingPolicy::AccuracyWeighted]
    {
        let units: Vec<ChipUnit<'_>> = chips
            .iter()
            .enumerate()
            .map(|(i, c)| {
                ChipUnit { id: i, chip: c, params: &golden, weight: 0.5 + 0.1 * i as f64 }
            })
            .collect();
        let cfg = WorkloadConfig {
            backend: Backend::Plan,
            policy,
            batch: 8,
            queue_depth: 2,
            requests,
            workers: 2,
            seed: 9,
        };
        let rep = serve(&units, &calib, &test, &cfg).unwrap();
        // conservation: every request id served exactly once, fleet-wide
        let mut ids: Vec<usize> =
            rep.per_chip.iter().flat_map(|c| c.request_ids.iter().copied()).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..requests).collect::<Vec<_>>(), "policy {policy:?}");
        assert_eq!(rep.requests, requests);
        assert_eq!(rep.samples, requests * 8);
        assert!(rep.sim_cycles > 0);
        assert_eq!(rep.per_chip.len(), 3);
        if policy == RoutingPolicy::RoundRobin {
            for c in &rep.per_chip {
                let k = c.request_ids.len();
                assert!(k == 13 || k == 14, "round-robin imbalance: {k}");
            }
        }
    }
}

#[test]
fn round_robin_serving_is_deterministic_in_seed() {
    let (arch, golden, calib, _train, test) = bundle();
    let chip =
        Chip::new(arch.clone()).array_n(8).inject(5, 42).detect().unwrap().threads(1);
    let chips = [chip.clone(), chip.mitigate(MaskKind::FapBypass)];
    let run = || {
        let units: Vec<ChipUnit<'_>> = chips
            .iter()
            .enumerate()
            .map(|(i, c)| ChipUnit { id: i, chip: c, params: &golden, weight: 1.0 })
            .collect();
        let cfg = WorkloadConfig {
            backend: Backend::Plan,
            policy: RoutingPolicy::RoundRobin,
            batch: 8,
            queue_depth: 2,
            requests: 24,
            workers: 2,
            seed: 33,
        };
        serve(&units, &calib, &test, &cfg).unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.correct, b.correct, "same seed must serve the same traffic");
    assert_eq!(a.samples, b.samples);
    for (ca, cb) in a.per_chip.iter().zip(&b.per_chip) {
        let (mut ia, mut ib) = (ca.request_ids.clone(), cb.request_ids.clone());
        ia.sort_unstable();
        ib.sort_unstable();
        assert_eq!(ia, ib, "chip {} routing changed across runs", ca.chip_id);
        assert_eq!(ca.correct, cb.correct);
    }
}

/// The acceptance scenario: at a 25% end-of-life fault rate, the FAP+T
/// health-managed fleet must serve measurably better accuracy over its
/// life than the unmitigated fleet (same chips, same traffic, same seed).
#[test]
fn managed_fleet_beats_unmitigated_at_25pct_eol() {
    let (arch, golden, calib, train, test) = bundle();
    let base = FleetConfig {
        chips: 4,
        array_n: 8,
        seed: 11,
        policy: RoutingPolicy::RoundRobin,
        hours: 20_000.0,
        life_steps: 3,
        yield_dist: YieldDist::Fixed(2),
        eol_fault_rate: 0.25,
        aging_beta: 2.0,
        slo_frac: 0.85,
        batch: 16,
        queue_depth: 2,
        batches_per_chip: 2,
        workers: 2,
        retrain_epochs: 2,
        retrain_downtime_hours: 100.0,
        max_retrains: 4,
        managed: true,
        escape_prob: 0.0,
        ..FleetConfig::default()
    };
    let run = |managed: bool| {
        let mut engine = Engine::new(Backend::Plan, None).unwrap();
        let cfg = FleetConfig { managed, ..base.clone() };
        let mut fleet =
            provision_fleet(&mut engine, cfg, &arch, &golden, &calib, &train, &test).unwrap();
        let out = run_lifetime(&mut engine, &mut fleet, &golden, &train, &test).unwrap();
        (fleet, out)
    };
    let (mfleet, mout) = run(true);
    let (ufleet, uout) = run(false);

    // the unmitigated fleet (never retired, ages the full life) really is
    // at ~25% faulty MACs by end of life — the scenario under test
    for c in &ufleet.chips {
        let r = c.aging.fault_rate();
        assert!(r > 0.15, "chip {} only aged to {r:.2} fault rate", c.id);
    }
    assert!(mout.total_samples > 0 && uout.total_samples > 0);
    let (ma, ua) = (mout.served_accuracy(), uout.served_accuracy());
    assert!(
        ma > ua + 0.05,
        "FAP+T health management ({ma:.3}) must beat unmitigated ({ua:.3})"
    );

    // the JSON record carries the headline fields the campaign promises
    let json = fleet_json(&mfleet, &mout, "plan").render();
    for key in [
        "\"fleet_accuracy\"",
        "\"samples_per_sec\"",
        "\"p50_latency_us\"",
        "\"p99_latency_us\"",
        "\"p999_latency_us\"",
        "\"offered_load_rps\"",
        "\"goodput_rps\"",
        "\"shed_fraction\"",
        "\"timeout_fraction\"",
        "\"mean_batch_fill\"",
        "\"conservation_ok\": true",
        "\"arrival\": \"poisson\"",
        "\"effective_yield\"",
        "\"retrain_events\"",
        "\"sim_cycles\"",
        "\"escape_prob\"",
        "\"sdc_samples\"",
        "\"sdc_fraction\"",
        "\"escaped_faulty_macs\"",
    ] {
        assert!(json.contains(key), "fleet.json missing {key}");
    }
    // at escape_prob 0 localization is (near-)exhaustive: SDC exposure
    // stays a sliver of the served traffic, not a systematic leak
    assert!(
        mout.sdc_fraction() < 0.5,
        "unexpected SDC exposure without forced escapes: {}",
        mout.sdc_fraction()
    );

    // the blind fleet is the opposite pole: its controller never ran
    // localization, so with every chip fabbed faulty (Fixed(2) defects)
    // all of its served traffic is SDC-exposed — the view must not
    // default to perfect knowledge and report zero escapes
    for c in &ufleet.chips {
        assert_eq!(c.known_faulty_macs(), 0, "blind chip {} must know nothing", c.id);
        assert!(c.escaped_faulty_macs() >= 2, "blind chip {} hides its defects", c.id);
    }
    assert_eq!(uout.sdc_samples, uout.total_samples, "blind fleet must be fully SDC-exposed");
    assert!((uout.sdc_fraction() - 1.0).abs() < 1e-12);
}

/// Escaped-fault SDC accounting: when every fault escapes the health
/// monitor's localization, the managed fleet believes its chips clean,
/// prunes nothing, and every served sample is exposed to silent data
/// corruption — which `fleet.json` must report alongside served accuracy.
#[test]
fn escaped_faults_are_accounted_as_sdc_traffic() {
    let (arch, golden, calib, train, test) = bundle();
    let cfg = FleetConfig {
        chips: 3,
        array_n: 8,
        seed: 21,
        policy: RoutingPolicy::RoundRobin,
        hours: 10_000.0,
        life_steps: 2,
        yield_dist: YieldDist::Fixed(2),
        eol_fault_rate: 0.2,
        aging_beta: 2.0,
        // SLO low enough that corrupted chips keep serving: the scenario
        // is about exposure accounting, not retirement
        slo_frac: 0.05,
        batch: 16,
        queue_depth: 2,
        batches_per_chip: 2,
        workers: 2,
        retrain_epochs: 1,
        retrain_downtime_hours: 50.0,
        max_retrains: 2,
        managed: true,
        escape_prob: 1.0,
        ..FleetConfig::default()
    };
    let mut engine = Engine::new(Backend::Plan, None).unwrap();
    let mut fleet =
        provision_fleet(&mut engine, cfg, &arch, &golden, &calib, &train, &test).unwrap();
    let out = run_lifetime(&mut engine, &mut fleet, &golden, &train, &test).unwrap();

    assert!(out.total_samples > 0, "fleet must have served traffic");
    // every chip fabbed with 2 defects and escape_prob 1.0: the
    // controller never detects anything, so all traffic is SDC-exposed
    for c in &fleet.chips {
        assert_eq!(c.known_faulty_macs(), 0, "chip {}: nothing must be detected", c.id);
        assert!(c.escaped_faulty_macs() >= 2, "chip {}: fab defects must escape", c.id);
        assert_eq!(c.sdc_samples, c.served_samples, "chip {}", c.id);
    }
    assert_eq!(out.sdc_samples, out.total_samples);
    assert!((out.sdc_fraction() - 1.0).abs() < 1e-12);
    assert!(out.escaped_faults_eol >= 3 * 2);
    let json = fleet_json(&fleet, &out, "plan").render();
    assert!(json.contains("\"escape_prob\": 1"), "missing escape_prob: {json}");
}

fn open_chips(arch: &Arch, n: usize) -> Vec<Chip> {
    (0..n)
        .map(|i| {
            Chip::new(arch.clone())
                .array_n(8)
                .inject(3 + i, 200 + i as u64)
                .detect()
                .unwrap()
                .mitigate(MaskKind::FapBypass)
                .threads(1)
        })
        .collect()
}

fn open_cfg(rate_rps: f64, offered: usize, execute: bool) -> OpenWorkloadConfig {
    OpenWorkloadConfig {
        backend: Backend::Plan,
        policy: RoutingPolicy::RoundRobin,
        arrival: ArrivalProcess::Poisson,
        rate_rps,
        offered,
        batcher: BatcherConfig {
            batch_max: 8,
            max_batch_age_us: 100.0,
            queue_timeout_us: 5_000.0,
            queue_depth: 1,
        },
        workers: 2,
        execute,
        seed: 13,
    }
}

/// Open-loop admission accounting under forced overload: every offered
/// request is served, shed, or timed out — exactly once — and the served
/// set really executes (samples and accuracy counted over it).
#[test]
fn open_loop_conserves_requests_under_shedding() {
    let (arch, golden, calib, _train, test) = bundle();
    let chips = open_chips(&arch, 2);
    let units: Vec<ChipUnit<'_>> = chips
        .iter()
        .enumerate()
        .map(|(i, c)| ChipUnit { id: i, chip: c, params: &golden, weight: 1.0 })
        .collect();
    // 1e10 req/s: the whole stream lands faster than any chip can drain
    // its 8-slot pool, so admission control must shed most of it
    let rep = serve_open(&units, &calib, &test, &open_cfg(1e10, 400, true)).unwrap();
    let open = rep.open.as_ref().unwrap();
    assert!(open.conservation_ok(), "served+shed+timed_out != offered");
    assert_eq!(open.offered, 400);
    assert!(open.shed > 0, "overload must shed");
    assert!(open.served > 0, "overload must still serve admitted traffic");
    assert!(open.shed_fraction() > 0.5, "shed fraction {}", open.shed_fraction());
    // each outcome appears exactly once, and Served ids match the per-chip
    // execution records one-for-one
    let mut served_ids: Vec<usize> =
        rep.per_chip.iter().flat_map(|c| c.request_ids.iter().copied()).collect();
    served_ids.sort_unstable();
    let mut expect: Vec<usize> = open
        .outcomes
        .iter()
        .enumerate()
        .filter(|(_, o)| matches!(o, RequestOutcome::Served { .. }))
        .map(|(id, _)| id)
        .collect();
    expect.sort_unstable();
    assert_eq!(served_ids, expect, "executed requests must be exactly the Served outcomes");
    assert_eq!(rep.samples, open.served, "one sample per served request");
    assert_eq!(rep.requests, open.served);
    assert!(rep.correct > 0, "served traffic must classify");
}

/// The open-loop serving stats are bit-identical across runs with the same
/// seed: arrivals, routing, batching, admission, and every latency are
/// virtual-clock quantities.
#[test]
fn open_loop_serving_is_deterministic_in_seed() {
    let (arch, golden, calib, _train, test) = bundle();
    let chips = open_chips(&arch, 3);
    let run = || {
        let units: Vec<ChipUnit<'_>> = chips
            .iter()
            .enumerate()
            .map(|(i, c)| {
                ChipUnit { id: i, chip: c, params: &golden, weight: 0.4 + 0.2 * i as f64 }
            })
            .collect();
        let mut cfg = open_cfg(0.0, 300, true); // auto rate
        cfg.policy = RoutingPolicy::AccuracyWeighted;
        serve_open(&units, &calib, &test, &cfg).unwrap()
    };
    let (a, b) = (run(), run());
    let (oa, ob) = (a.open.as_ref().unwrap(), b.open.as_ref().unwrap());
    assert_eq!(oa.outcomes, ob.outcomes, "request outcomes changed across runs");
    assert_eq!(oa.latencies_us, ob.latencies_us, "latency distribution changed");
    assert_eq!(oa.virtual_secs, ob.virtual_secs);
    assert_eq!(oa.batches, ob.batches);
    assert_eq!(a.correct, b.correct, "same plan must execute the same traffic");
    assert_eq!(a.samples, b.samples);
    assert!(oa.p999_latency_us() >= oa.p99_latency_us());
    assert!(oa.p99_latency_us() >= oa.p50_latency_us());
}

/// The tentpole's serving claim in miniature: at the same offered load, a
/// dynamic batching window (dispatch on `max_batch_age`) serves strictly
/// more traffic than fixed-batch serving (full batches only), because a
/// trickle never fills a 16-slot window before requests hit the deadline.
#[test]
fn dynamic_batching_beats_fixed_batch_goodput() {
    let (arch, golden, calib, _train, test) = bundle();
    let chips = open_chips(&arch, 1);
    let units: Vec<ChipUnit<'_>> = chips
        .iter()
        .enumerate()
        .map(|(i, c)| ChipUnit { id: i, chip: c, params: &golden, weight: 1.0 })
        .collect();
    let run = |age_us: f64| {
        let mut cfg = open_cfg(5e4, 600, false); // 20 µs gaps: a trickle
        cfg.batcher =
            BatcherConfig { batch_max: 16, max_batch_age_us: age_us, ..cfg.batcher };
        let rep = serve_open(&units, &calib, &test, &cfg).unwrap();
        rep.open.unwrap()
    };
    let dynamic = run(50.0);
    let fixed = run(f64::INFINITY);
    assert!(dynamic.conservation_ok() && fixed.conservation_ok());
    assert!(
        dynamic.served > fixed.served,
        "dynamic window must serve more of the trickle: {} vs {}",
        dynamic.served,
        fixed.served
    );
    assert!(
        dynamic.goodput_rps() > fixed.goodput_rps(),
        "dynamic goodput {} must beat fixed {}",
        dynamic.goodput_rps(),
        fixed.goodput_rps()
    );
    assert!(fixed.timed_out > 0, "fixed-batch stragglers must be accounted as timeouts");
    assert_eq!(fixed.served % 16, 0, "fixed mode dispatches full batches only");
}

/// Smooth weighted round-robin converges to the accuracy weights: over T
/// picks, every lane's traffic share lands within O(1/T) of its normalized
/// weight, for random weight vectors.
#[test]
fn wrr_traffic_shares_converge_to_weights() {
    prop::check("wrr_shares", 0xF1EE7, 40, |rng| {
        let lanes = 2 + rng.below(5);
        let weights: Vec<f64> = (0..lanes).map(|_| 0.05 + rng.f64()).collect();
        let wsum: f64 = weights.iter().sum();
        let picks = 600usize;
        let mut counts = vec![0usize; lanes];
        let mut picker = WrrPicker::new(&weights);
        for _ in 0..picks {
            counts[picker.pick()] += 1;
        }
        prop_assert!(
            counts.iter().sum::<usize>() == picks,
            "every pick lands on exactly one lane"
        );
        for (i, (&c, w)) in counts.iter().zip(&weights).enumerate() {
            let expect = picks as f64 * w / wsum;
            let err = (c as f64 - expect).abs();
            prop_assert!(
                err <= 1.0 + lanes as f64,
                "lane {i}: {c} picks vs expected {expect:.1} (weights {weights:?})"
            );
        }
        Ok(())
    });
}
