//! Property tests over the aging fault model (`faults::aging`) — the
//! invariants the fleet's lifetime health loop relies on: fault maps are
//! supersets over time (permanent faults never heal), sampled counts
//! track the Weibull expectation, and the map fingerprint changes exactly
//! when the map does (plan-cache invalidation safety).

use repro::faults::aging::{AgingChip, AgingModel};
use repro::faults::FaultSpec;
use repro::prop_assert;
use repro::util::prop;

#[test]
fn prop_aging_maps_are_supersets_over_time() {
    prop::check("aging_superset", 0xA6E1, 30, |rng| {
        let n = 4 + rng.below(13); // 4..=16
        let beta = 1.0 + rng.f64() * 2.0;
        let tau = 20_000.0 + rng.f64() * 80_000.0;
        let model = AgingModel { tau_hours: tau, beta, spec: FaultSpec::new(n) };
        let initial = rng.below(n * n / 4 + 1);
        let mut chip = AgingChip::new(model, initial, rng.next_u64());
        prop_assert!(
            chip.fault_map().faulty_mac_count() == initial,
            "fab defects {} != {initial}",
            chip.fault_map().faulty_mac_count()
        );
        let mut prev = chip.snapshot();
        for _ in 0..8 {
            let newly = chip.advance(tau / 6.0);
            let cur = chip.fault_map();
            for (r, c) in prev.faulty_macs() {
                prop_assert!(cur.is_faulty(r, c), "fault healed at ({r},{c})");
            }
            // strictness: the faulty set grew exactly when advance said so
            let grew = cur.faulty_mac_count() > prev.faulty_mac_count();
            prop_assert!(
                grew == (newly > 0),
                "advance reported {newly} new faults but map grew={grew}"
            );
            prev = chip.snapshot();
        }
        Ok(())
    });
}

#[test]
fn prop_sampled_counts_track_expectation() {
    prop::check("aging_expectation", 0xE8A2, 12, |rng| {
        let n = 24 + rng.below(17); // 24..=40: enough MACs for statistics
        let beta = 1.0 + rng.f64() * 1.5;
        let model = AgingModel { tau_hours: 40_000.0, beta, spec: FaultSpec::new(n) };
        let mut chip = AgingChip::new(model, 0, rng.next_u64());
        let steps = 16;
        let horizon = 60_000.0;
        for _ in 0..steps {
            chip.advance(horizon / steps as f64);
        }
        let got = chip.fault_map().faulty_mac_count() as f64;
        let want = model.expected_faulty_macs(horizon) as f64;
        let tol = (want * 0.2).max(8.0);
        prop_assert!(
            (got - want).abs() <= tol,
            "sampled {got} vs expected {want} (n={n}, beta={beta:.2})"
        );
        Ok(())
    });
}

#[test]
fn prop_fingerprint_changes_iff_map_changes() {
    prop::check("aging_fingerprint", 0xF1A3, 30, |rng| {
        let n = 4 + rng.below(9); // 4..=12
        let model =
            AgingModel { tau_hours: 30_000.0, beta: 2.0, spec: FaultSpec::new(n) };
        let mut chip = AgingChip::new(model, rng.below(3), rng.next_u64());
        // small steps so some advances strike zero new MACs
        for _ in 0..12 {
            let before = chip.fault_map().fingerprint();
            let newly = chip.advance(1_500.0);
            let after = chip.fault_map().fingerprint();
            if newly == 0 {
                prop_assert!(after == before, "fingerprint moved with no new faults");
            } else {
                prop_assert!(
                    after != before,
                    "{newly} new faults but the fingerprint is stale — \
                     a cached plan would silently serve the wrong chip"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_eol_calibration_hits_target_rate() {
    prop::check("aging_eol_calibration", 0xE01C, 50, |rng| {
        let rate = 0.05 + rng.f64() * 0.6;
        let hours = 10_000.0 + rng.f64() * 90_000.0;
        let beta = 1.0 + rng.f64() * 2.0;
        let m = AgingModel::with_eol_rate(FaultSpec::new(16), rate, hours, beta);
        let got = m.expected_fault_rate(hours);
        prop_assert!(
            (got - rate).abs() < 1e-9,
            "calibrated model reaches {got} at end of life, wanted {rate}"
        );
        Ok(())
    });
}
