//! Property tests over fault injection and post-fab localization.

use repro::faults::{detect, inject_clustered, inject_uniform, FaultSpec};
use repro::prop_assert;
use repro::util::{prop, Rng};

/// Localization never reports a false positive, and with the default
/// pattern set recall is total for observable faults on these grids.
#[test]
fn prop_detect_sound_and_complete() {
    prop::check("detect_sound_complete", 0xC1, 10, |rng| {
        let n = 8 << rng.below(2); // 8 or 16
        let k = rng.below(n * n / 4);
        let fm = inject_uniform(FaultSpec::new(n), k, rng);
        let rep = detect::localize_from_map(&fm, Default::default());
        let truth = fm.faulty_macs();
        for f in &rep.faulty {
            prop_assert!(truth.contains(f), "false positive {f:?}");
        }
        prop_assert!(
            rep.faulty.len() == truth.len(),
            "missed {} of {} faults",
            truth.len() - rep.faulty.len(),
            truth.len()
        );
        Ok(())
    });
}

/// Injection respects the requested count exactly for both spatial models.
#[test]
fn prop_injection_count_exact() {
    prop::check("injection_count", 0xC2, 25, |rng| {
        let n = 2 + rng.below(30);
        let k = rng.below(n * n + 1);
        let u = inject_uniform(FaultSpec::new(n), k, rng);
        prop_assert!(u.faulty_mac_count() == k, "uniform: {} != {k}", u.faulty_mac_count());
        let c = inject_clustered(FaultSpec::new(n), k, 1 + rng.below(4), rng);
        prop_assert!(c.faulty_mac_count() == k, "clustered: {} != {k}", c.faulty_mac_count());
        Ok(())
    });
}

/// Uniform injection is spatially uniform-ish: across many draws every
/// MAC position gets hit (no dead zones from the index arithmetic).
#[test]
fn prop_injection_covers_grid() {
    let n = 8;
    let mut hit = vec![false; n * n];
    let mut rng = Rng::new(0xC3);
    for _ in 0..120 {
        let fm = inject_uniform(FaultSpec::new(n), 8, &mut rng);
        for (r, c) in fm.faulty_macs() {
            hit[r * n + c] = true;
        }
    }
    let misses = hit.iter().filter(|&&h| !h).count();
    assert!(misses == 0, "{misses} MAC positions never faulted in 120 draws");
}

/// Detection cost grows ~logarithmically with grid size for a single
/// fault (binary search), not linearly.
#[test]
fn prop_detect_cost_sublinear() {
    prop::check("detect_cost", 0xC4, 6, |rng| {
        let small = inject_uniform(FaultSpec::new(8), 1, rng);
        let big = inject_uniform(FaultSpec::new(64), 1, rng);
        let rs = detect::localize_from_map(&small, Default::default());
        let rb = detect::localize_from_map(&big, Default::default());
        // 8x more rows but only ~2x the probes (log2 8=3 -> log2 64=6)
        prop_assert!(
            rb.array_runs <= rs.array_runs * 4,
            "cost scaled poorly: {} -> {}",
            rs.array_runs,
            rb.array_runs
        );
        Ok(())
    });
}
