//! Property tests: the compiled chip-plan executor ([`repro::exec`]) is
//! bit-exact with the naive PE-chain simulator across random shapes, fault
//! maps, mitigations, batch sizes and thread counts — including
//! partial-height tiles (K % N != 0, K < N) and partial-width tiles.
//!
//! Uses the in-repo harness (`rust/src/util/prop.rs`; the offline registry
//! has no proptest). Failing cases replay with `PROP_REPLAY=<seed>`.

use repro::exec::{
    dot_wrapping, kernel, ChipPlan, ExecScratch, Kernel, MatmulPlan, PanelOptions, WorkerPool,
};
use repro::faults::{FaultMap, StuckAt};
use repro::mapping::MaskKind;
use repro::model::arch::mnist;
use repro::prop_assert;
use repro::systolic::TiledMatmul;
use repro::util::{prop, Rng};

fn random_fault_map(rng: &mut Rng, n: usize, max_faults: usize) -> FaultMap {
    let mut fm = FaultMap::healthy(n);
    for _ in 0..rng.below(max_faults + 1) {
        fm.add(StuckAt {
            row: rng.below(n) as u16,
            col: rng.below(n) as u16,
            bit: rng.below(32) as u8,
            value: rng.bool(0.5),
        });
    }
    fm
}

fn random_case(rng: &mut Rng, k: usize, m: usize, batch: usize) -> (Vec<i32>, Vec<i32>) {
    let a: Vec<i32> = (0..batch * k).map(|_| rng.below(255) as i32 - 127).collect();
    let mut w: Vec<i32> = (0..k * m).map(|_| rng.below(255) as i32 - 127).collect();
    // sprinkle exact zeros so the additive-constant fold path is exercised
    for v in w.iter_mut() {
        if rng.bool(0.15) {
            *v = 0;
        }
    }
    (a, w)
}

/// The core oracle property: plan executor == naive PE-chain walk for any
/// (shape, fault map, mitigation) triple, including partial tiles.
#[test]
fn prop_plan_executor_matches_naive_chain() {
    prop::check("plan_matches_naive", 0xE1, 60, |rng| {
        let n = 2 + rng.below(7);
        // bias toward non-multiples of n so partial-height/width tiles are
        // the common case, and allow k < n (single clock-gated pass)
        let k = 1 + rng.below(3 * n);
        let m = 1 + rng.below(3 * n);
        let batch = 1 + rng.below(6);
        let fm = random_fault_map(rng, n, 8);
        let (a, w) = random_case(rng, k, m, batch);
        for (kind, byp) in [(MaskKind::Unmitigated, false), (MaskKind::FapBypass, true)] {
            let plan = MatmulPlan::compile(&fm, kind, &w, k, m);
            let got = plan.execute(&a, batch);
            let want = TiledMatmul::new(&fm, byp).matmul(&a, &w, batch, k, m);
            prop_assert!(
                got == want,
                "{kind:?}: n={n} k={k} m={m} b={batch} faults={}",
                fm.faults().len()
            );
        }
        Ok(())
    });
}

/// Partial-height passes clock-gate unused rows: faults below the active
/// row range must not leak into the plan's output.
#[test]
fn prop_partial_height_gates_inactive_rows() {
    prop::check("partial_height_gating", 0xE2, 40, |rng| {
        let n = 3 + rng.below(6);
        let k = 1 + rng.below(n - 1); // strictly partial: K < N
        let m = 1 + rng.below(2 * n);
        let batch = 1 + rng.below(4);
        // plant a fault strictly below the active rows
        let row = (k + rng.below(n - k)) as u16;
        let mut fm = random_fault_map(rng, n, 3);
        fm.add(StuckAt { row, col: rng.below(n) as u16, bit: 30, value: true });
        let (a, w) = random_case(rng, k, m, batch);
        let plan = MatmulPlan::compile(&fm, MaskKind::Unmitigated, &w, k, m);
        let want = TiledMatmul::new(&fm, false).matmul(&a, &w, batch, k, m);
        prop_assert!(plan.execute(&a, batch) == want, "n={n} k={k} m={m} row={row}");
        Ok(())
    });
}

/// Batch-sharded threading is bit-exact with single-thread execution for
/// any thread count, including counts exceeding the batch.
#[test]
fn prop_threaded_execution_is_bit_exact() {
    prop::check("threaded_bit_exact", 0xE3, 30, |rng| {
        let n = 2 + rng.below(6);
        let k = 1 + rng.below(3 * n);
        let m = 1 + rng.below(3 * n);
        let batch = 1 + rng.below(12);
        let fm = random_fault_map(rng, n, 6);
        let (a, w) = random_case(rng, k, m, batch);
        let plan = MatmulPlan::compile(&fm, MaskKind::Unmitigated, &w, k, m);
        let single = plan.execute(&a, batch);
        for threads in [2usize, 3, 5, batch + 3] {
            prop_assert!(
                plan.execute_threaded(&a, batch, threads) == single,
                "threads={threads} n={n} k={k} m={m} b={batch}"
            );
        }
        Ok(())
    });
}

/// The packed-panel microkernel is bit-identical to an explicit
/// column-at-a-time [`dot_wrapping`] reference across random shapes —
/// partial-height/width tiles, tail panels (`m % PANEL_NR != 0`), tail
/// rows (`batch % MICRO_MR != 0`) and batch = 1 — under FAP bypass, where
/// every column lowers to the dense GEMM core. Chain columns (unmitigated
/// live faults) are cross-checked against the naive PE-chain walk in the
/// same iteration, so packed + chain outputs interleave in one output
/// buffer exactly as the executor produces them.
#[test]
fn prop_packed_microkernel_matches_dot_wrapping() {
    prop::check("packed_matches_dot", 0xE7, 50, |rng| {
        let n = 2 + rng.below(7);
        let k = 1 + rng.below(3 * n);
        let m = 1 + rng.below(3 * n);
        // force batch = 1 often: the single-row edge kernel must be as
        // correct as the 4x4 tile path
        let batch = if rng.bool(0.3) { 1 } else { 1 + rng.below(9) };
        let fm = random_fault_map(rng, n, 8);
        let (a, w) = random_case(rng, k, m, batch);

        // FAP bypass: every column is dense -> pure packed microkernel;
        // reference = dot_wrapping over the bypass-folded weight columns
        let plan = MatmulPlan::compile(&fm, MaskKind::FapBypass, &w, k, m);
        prop_assert!(plan.stats().chain_cols == 0, "bypass must be pure GEMM");
        let got = plan.execute(&a, batch);
        for b in 0..batch {
            let row = &a[b * k..(b + 1) * k];
            for j in 0..m {
                // static mapping r = i mod N, c = j mod N: bypassed MACs
                // are exactly zero effective weights
                let col: Vec<i32> = (0..k)
                    .map(|kk| if fm.is_faulty(kk % n, j % n) { 0 } else { w[kk * m + j] })
                    .collect();
                let want = dot_wrapping(row, &col);
                prop_assert!(
                    got[b * m + j] == want,
                    "packed != dot: n={n} k={k} m={m} b={b}/{batch} j={j}"
                );
            }
        }

        // unmitigated: chain columns live alongside packed dense columns;
        // the naive PE-chain walk is the oracle for the mixture
        let plan = MatmulPlan::compile(&fm, MaskKind::Unmitigated, &w, k, m);
        let got = plan.execute(&a, batch);
        let want = TiledMatmul::new(&fm, false).matmul(&a, &w, batch, k, m);
        prop_assert!(got == want, "chain mix: n={n} k={k} m={m} batch={batch}");
        Ok(())
    });
}

/// The dispatched SIMD kernel, the runtime-width scalar reference at the
/// same panel width, and the cycle-level sim agree bit-for-bit across
/// random shapes, fault maps, mitigations, chain-segment mixes, partial
/// tiles and batch = 1. On AVX2/NEON hosts this pins the real vector
/// kernels against the scalar oracle on every case.
#[test]
fn prop_simd_matches_scalar_reference_and_sim() {
    prop::check("simd_vs_scalar", 0xE9, 50, |rng| {
        let n = 2 + rng.below(7);
        let k = 1 + rng.below(3 * n);
        let m = 1 + rng.below(3 * n);
        // batch = 1 often: the 1-row SIMD edge kernel needs equal coverage
        let batch = if rng.bool(0.3) { 1 } else { 1 + rng.below(9) };
        let fm = random_fault_map(rng, n, 8);
        let (a, w) = random_case(rng, k, m, batch);
        for (kind, byp) in [(MaskKind::Unmitigated, false), (MaskKind::FapBypass, true)] {
            let plan = MatmulPlan::compile(&fm, kind, &w, k, m);
            let got = plan.execute(&a, batch);
            let oracle = Kernel::scalar_reference(plan.panel_nr());
            let reference = plan.execute_with_kernel(&oracle, &a, batch);
            prop_assert!(
                got == reference,
                "{kind:?} isa={:?}: n={n} k={k} m={m} b={batch}",
                kernel().isa()
            );
            let want = TiledMatmul::new(&fm, byp).matmul(&a, &w, batch, k, m);
            prop_assert!(got == want, "{kind:?} vs sim: n={n} k={k} m={m} b={batch}");
        }
        Ok(())
    });
}

/// Every panel layout the dispatcher can pick — both widths (4 = NEON/
/// scalar, 8 = AVX2) in both element widths — executes bit-exact through
/// the runtime-width scalar reference kernel on any host, so the AVX2
/// panel format stays pinned even where AVX2 cannot run.
#[test]
fn prop_panel_layouts_bit_exact_at_all_widths() {
    prop::check("panel_widths", 0xEA, 30, |rng| {
        let n = 2 + rng.below(6);
        let k = 1 + rng.below(3 * n);
        let m = 1 + rng.below(3 * n);
        let batch = 1 + rng.below(6);
        let fm = random_fault_map(rng, n, 6);
        let (a, w) = random_case(rng, k, m, batch);
        for (kind, byp) in [(MaskKind::Unmitigated, false), (MaskKind::FapBypass, true)] {
            let want = TiledMatmul::new(&fm, byp).matmul(&a, &w, batch, k, m);
            for nr in [4usize, 8] {
                for allow_i8 in [false, true] {
                    let opts = PanelOptions { nr, allow_i8 };
                    let plan = MatmulPlan::compile_opts(&fm, kind, &w, k, m, opts);
                    let got = plan.execute_with_kernel(&Kernel::scalar_reference(nr), &a, batch);
                    prop_assert!(
                        got == want,
                        "{kind:?} nr={nr} i8={allow_i8}: n={n} k={k} m={m} b={batch}"
                    );
                }
            }
        }
        Ok(())
    });
}

/// Wrapping-overflow extremes: activations saturating the i32 range
/// (`i32::MIN`/`i32::MAX` accumulands) through both panel element widths
/// — quantized-range weights exercise the i8 widening path, wide weights
/// force i32 panels — all bit-exact with the scalar reference and the
/// cycle-level sim (wrap, never saturate, on every ISA).
#[test]
fn prop_wrapping_extremes_bit_exact() {
    prop::check("simd_extremes", 0xEB, 30, |rng| {
        let n = 2 + rng.below(5);
        let k = 1 + rng.below(2 * n);
        let m = 1 + rng.below(2 * n);
        let batch = 1 + rng.below(5);
        let fm = random_fault_map(rng, n, 6);
        let a: Vec<i32> = (0..batch * k)
            .map(|_| match rng.below(4) {
                0 => i32::MAX,
                1 => i32::MIN,
                _ => rng.below(1 << 16) as i32 - (1 << 15),
            })
            .collect();
        // i8-range weights (the quantized datapath) -> i8 panels
        let w8: Vec<i32> = (0..k * m).map(|_| rng.below(255) as i32 - 127).collect();
        // wide weights (incl. near-i32::MIN) -> i32 panels
        let w32: Vec<i32> = (0..k * m)
            .map(|_| {
                if rng.bool(0.3) {
                    i32::MIN + rng.below(1000) as i32
                } else {
                    rng.below(1 << 20) as i32 - (1 << 19)
                }
            })
            .collect();
        for (w, tag) in [(&w8, "i8"), (&w32, "i32")] {
            let plan = MatmulPlan::compile(&fm, MaskKind::Unmitigated, w, k, m);
            if tag == "i8" {
                prop_assert!(
                    plan.stats().i8_tiles == plan.stats().tiles,
                    "quantized-range weights must pack i8 panels"
                );
            }
            let got = plan.execute(&a, batch);
            let oracle = Kernel::scalar_reference(plan.panel_nr());
            let reference = plan.execute_with_kernel(&oracle, &a, batch);
            prop_assert!(got == reference, "{tag}: n={n} k={k} m={m} b={batch}");
            let want = TiledMatmul::new(&fm, false).matmul(&a, w, batch, k, m);
            prop_assert!(got == want, "{tag} vs sim: n={n} k={k} m={m} b={batch}");
        }
        Ok(())
    });
}

/// Pooled execution (persistent spawn-once workers) is bit-exact with
/// single-thread execution for any lane count — including lanes exceeding
/// the batch — and stays exact when one pool is reused across many plans
/// and shapes (the fleet serving pattern).
#[test]
fn prop_pooled_execution_is_bit_exact() {
    let pools: Vec<WorkerPool> = [1usize, 2, 3, 6].into_iter().map(WorkerPool::new).collect();
    prop::check("pooled_bit_exact", 0xE8, 30, |rng| {
        let n = 2 + rng.below(6);
        let k = 1 + rng.below(3 * n);
        let m = 1 + rng.below(3 * n);
        let batch = 1 + rng.below(12);
        let fm = random_fault_map(rng, n, 6);
        let (a, w) = random_case(rng, k, m, batch);
        let plan = MatmulPlan::compile(&fm, MaskKind::Unmitigated, &w, k, m);
        let single = plan.execute(&a, batch);
        for pool in &pools {
            prop_assert!(
                plan.execute_pooled(&a, batch, pool) == single,
                "lanes={} n={n} k={k} m={m} b={batch}",
                pool.lanes()
            );
        }
        Ok(())
    });
}

/// Compile-once / run-many: one plan serves many activation batches (the
/// campaign access pattern), matching the naive simulator on each.
#[test]
fn prop_plan_reuse_across_batches() {
    prop::check("plan_reuse", 0xE4, 20, |rng| {
        let n = 2 + rng.below(6);
        let k = 1 + rng.below(2 * n);
        let m = 1 + rng.below(2 * n);
        let fm = random_fault_map(rng, n, 6);
        let (_, w) = random_case(rng, k, m, 1);
        let plan = MatmulPlan::compile(&fm, MaskKind::Unmitigated, &w, k, m);
        let mut naive = TiledMatmul::new(&fm, false);
        let mut scratch = ExecScratch::new();
        for run in 0..4 {
            let batch = 1 + rng.below(8);
            let a: Vec<i32> = (0..batch * k).map(|_| rng.below(255) as i32 - 127).collect();
            let got = scratch.run(&plan, &a, batch).to_vec();
            let want = naive.matmul(&a, &w, batch, k, m);
            prop_assert!(got == want, "run={run} b={batch} n={n} k={k} m={m}");
        }
        Ok(())
    });
}

/// FAP lowering collapses every column onto the dense GEMM core (no chain
/// programs survive bypass), and still matches the bypassed chain walk.
#[test]
fn prop_fap_bypass_is_pure_gemm() {
    prop::check("fap_pure_gemm", 0xE5, 30, |rng| {
        let n = 2 + rng.below(6);
        let k = 1 + rng.below(3 * n);
        let m = 1 + rng.below(3 * n);
        let batch = 1 + rng.below(4);
        let fm = random_fault_map(rng, n, 10);
        let (a, w) = random_case(rng, k, m, batch);
        let plan = MatmulPlan::compile(&fm, MaskKind::FapBypass, &w, k, m);
        prop_assert!(
            plan.stats().chain_cols == 0,
            "bypass left {} chain columns",
            plan.stats().chain_cols
        );
        let want = TiledMatmul::new(&fm, true).matmul(&a, &w, batch, k, m);
        prop_assert!(plan.execute(&a, batch) == want, "n={n} k={k} m={m}");
        Ok(())
    });
}

/// Chip-plan invalidation: a plan compiled for one fault map never claims
/// to match a map with different datapath behaviour, and always matches a
/// byte-identical re-injection.
#[test]
fn prop_chip_plan_fingerprint_invalidation() {
    prop::check("plan_invalidation", 0xE6, 25, |rng| {
        let arch = mnist();
        let n = 16;
        let fm = random_fault_map(rng, n, 12);
        let plan = ChipPlan::compile(&arch, &fm, MaskKind::FapBypass);
        prop_assert!(plan.matches(&fm), "plan must match its own map");
        // perturb one MAC -> different chip
        let mut fm2 = fm.clone();
        fm2.add(StuckAt {
            row: rng.below(n) as u16,
            col: rng.below(n) as u16,
            bit: rng.below(32) as u8,
            value: rng.bool(0.5),
        });
        if fm2.fingerprint() != fm.fingerprint() {
            prop_assert!(!plan.matches(&fm2), "stale plan accepted a new chip");
        }
        Ok(())
    });
}
